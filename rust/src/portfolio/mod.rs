//! Adaptive solver portfolio + fleet-wide warm-start cache — the layer
//! between `sched` (which batches subproblems across documents) and
//! `solvers` (which solve one quantized Ising instance each).
//!
//! The paper's evaluation (COBI vs. Tabu vs. brute force; TTS/ETS curves
//! in Figs. 7/8) shows the best solver depends on subproblem size and
//! precision, and reuse-aware Ising machines show solve-to-solve reuse is
//! where large wins hide. This module exploits both observations:
//!
//! * [`SolverPortfolio`] — owns one instance of every backend (the COBI
//!   device, Tabu, SA, greedy descent, exact-for-tiny-N, and the
//!   Snowball sharded parallel-spin solver for the largest buckets)
//!   behind the
//!   [`IsingSolver`] trait and routes each subproblem by a
//!   [`RoutePolicy`] (`static`, `size-tiered`, or epsilon-greedy
//!   `bandit` over per-(backend, size-bucket) running quality/latency
//!   stats). It implements the pool's `PoolSolver` contract, so
//!   [`DevicePool`](crate::sched::DevicePool) hosts it like any other
//!   backend (`[portfolio] enabled = true`, or
//!   `[sched] backend = "portfolio"`).
//! * [`WarmStartCache`] — keyed by a structural fingerprint of the
//!   quantized instance (the exact tier hashes the **integer coefficient
//!   tuple**, allocation-free — see `cache::exact_key`); exact hits are
//!   served directly (zero device time), near hits become initial spin
//!   configurations for warm-started solvers
//!   ([`IsingSolver::solve_from`], or oscillator phase initialisation on
//!   COBI). Shared fleet-wide across all pool devices via
//!   [`PortfolioShared`].
//!
//! Hot path: the software backends (Tabu, SA, greedy) are long-lived and
//! own their `SolveScratch`, so routed solves reuse buffers across
//! requests and run the integer `SolverKernel` on quantized instances —
//! routing adds no per-request allocation beyond the dispatch itself.
//! * [`PortfolioMetrics`] — per-backend route counts and latency
//!   histograms plus cache hit/miss/warm rates, snapshotted into
//!   `ServiceMetrics` next to the pool counters.
//!
//! Determinism contract (DESIGN.md decisions #9–#10): with
//! `policy = "static"` and the cache disabled, the portfolio is
//! byte-identical to hosting the static backend directly on the pool —
//! pinned by a bench_10 test against the sequential path. Any other
//! configuration trades that replay property for adaptivity: bandit
//! stats and cache contents depend on fleet history (routing itself
//! stays deterministic given the request seed).

pub mod cache;
pub mod policy;

pub use cache::{CacheOutcome, CacheStats, WarmStartCache};
pub use policy::{
    size_bucket, BackendKind, BanditStats, CellStats, RoutePolicy, N_BUCKETS, SIZE_BOUNDS,
};

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::cobi::{CobiDevice, SeededGroup};
use crate::config::{PortfolioConfig, Settings};
use crate::ising::Ising;
use crate::runtime::ArtifactRuntime;
use crate::sched::pool::PoolSolver;
use crate::service::metrics::Histogram;
use crate::solvers::exact::ExactIsingSolver;
use crate::solvers::greedy::GreedyDescent;
use crate::solvers::sa::SaSolver;
use crate::solvers::snowball::SnowballSolver;
use crate::solvers::tabu::TabuSolver;
use crate::solvers::{IsingSolver, SolveResult};
use crate::util::rng::Pcg32;

/// RNG stream id for the bandit's exploration draws (keyed by the request
/// seed, so routing replays deterministically per document). `pub(crate)`
/// so the RNG stream audit in `util::rng` can assert it never collides
/// with another named stream.
pub(crate) const BANDIT_STREAM: u64 = 0xBA2D17;

/// Hard ceiling on the exact backend's exhaustive enumeration (2^n
/// states; the config value is clamped here).
const EXACT_HARD_CAP: usize = 20;

/// Fleet-wide portfolio telemetry: route counts and latency per backend,
/// bandit statistics, and warm-start-cache counters. One instance is
/// shared by every portfolio device in a pool (via [`PortfolioShared`])
/// and snapshotted into `ServiceMetrics`.
#[derive(Debug, Clone)]
pub struct PortfolioMetrics {
    /// Solve requests routed to each backend (`BackendKind::index` order).
    pub routes: [u64; BackendKind::COUNT],
    /// Per-backend dispatch-latency histograms (same indexing).
    pub backend_latency: Vec<Histogram>,
    /// Per-(backend, size-bucket) running quality/latency stats.
    pub stats: BanditStats,
    /// Warm-start-cache counters (filled in at snapshot time).
    pub cache: CacheStats,
}

impl Default for PortfolioMetrics {
    fn default() -> Self {
        Self {
            routes: [0; BackendKind::COUNT],
            backend_latency: vec![Histogram::latency(); BackendKind::COUNT],
            stats: BanditStats::default(),
            cache: CacheStats::default(),
        }
    }
}

impl PortfolioMetrics {
    /// Requests routed to `b`.
    pub fn route_count(&self, b: BackendKind) -> u64 {
        self.routes[b.index()]
    }

    /// Total routed requests across all backends.
    pub fn total_routes(&self) -> u64 {
        self.routes.iter().sum()
    }

    /// One-line telemetry fragment for service reports.
    pub fn report(&self) -> String {
        let mut routes = String::new();
        for b in BackendKind::ALL {
            if self.routes[b.index()] > 0 {
                routes.push_str(&format!(" {}={}", b.name(), self.routes[b.index()]));
            }
        }
        if routes.is_empty() {
            routes.push_str(" none");
        }
        let mut lat = String::new();
        for b in BackendKind::ALL {
            let h = &self.backend_latency[b.index()];
            if h.count() > 0 {
                lat.push_str(&format!(" {}[{}]", b.name(), h.summary()));
            }
        }
        let mut out = format!("portfolio: routes{routes} | {}", self.cache.report());
        if !lat.is_empty() {
            out.push_str(&format!(" | lat{lat}"));
        }
        out
    }
}

/// The state shared by every portfolio device in one pool: the fleet-wide
/// warm-start cache and the combined telemetry. Created once by
/// `DevicePool::start` and cloned (cheap `Arc` clones) into each device's
/// [`SolverPortfolio`].
#[derive(Clone)]
pub struct PortfolioShared {
    /// Fleet-shared telemetry block.
    pub metrics: Arc<Mutex<PortfolioMetrics>>,
    /// Fleet-shared warm-start cache.
    pub cache: Arc<WarmStartCache>,
}

impl PortfolioShared {
    /// Fresh shared state per `cfg` (one per `DevicePool`).
    pub fn new(cfg: &PortfolioConfig) -> Self {
        Self {
            metrics: Arc::new(Mutex::new(PortfolioMetrics::default())),
            cache: Arc::new(WarmStartCache::new(cfg.cache_capacity)),
        }
    }

    /// Telemetry snapshot with current cache counters merged in.
    pub fn snapshot(&self) -> PortfolioMetrics {
        let mut m = self.metrics.lock().unwrap().clone();
        m.cache = self.cache.stats();
        m
    }
}

/// Derive a per-instance seed from a request seed (splitmix-style), used
/// by the cache-enabled COBI path where instances solve individually.
fn mix(seed: u64, k: u64) -> u64 {
    let mut z = seed ^ k.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z ^ (z >> 31)
}

/// Adaptive multi-backend Ising solver (see module docs).
///
/// # Examples
///
/// ```
/// use cobi_es::config::Settings;
/// use cobi_es::ising::Ising;
/// use cobi_es::portfolio::SolverPortfolio;
///
/// let mut settings = Settings::default();
/// settings.portfolio.policy = "size-tiered".into();
/// let mut portfolio = SolverPortfolio::from_settings(&settings, 7, None, None).unwrap();
///
/// let mut inst = Ising::new(6);
/// inst.set_pair(0, 5, -2.0); // ferromagnetic pair
/// // 6 spins routes to the exact backend: a true ground state comes back
/// let r = portfolio.solve_one(&inst, 0xFEED).unwrap();
/// assert_eq!(r.spins[0], r.spins[5]);
/// assert!((inst.energy(&r.spins) - r.energy).abs() < 1e-9);
/// ```
pub struct SolverPortfolio {
    policy: RoutePolicy,
    static_backend: BackendKind,
    epsilon: f64,
    exact_max_n: usize,
    latency_weight: f64,
    cache_enabled: bool,
    cobi: CobiDevice,
    tabu: TabuSolver,
    sa: SaSolver,
    greedy: GreedyDescent,
    exact: ExactIsingSolver,
    snowball: SnowballSolver,
    shared: PortfolioShared,
    /// Fleet energy ledger + subsystem attribution; the portfolio
    /// charges its ROUTED backend per fresh solve (`None` = no
    /// accounting, e.g. standalone portfolios).
    ledger: Option<(std::sync::Arc<crate::obs::EnergyLedger>, crate::obs::Subsystem)>,
    /// Seed stream for the unseeded [`IsingSolver`] entry points.
    seeds: Pcg32,
}

impl SolverPortfolio {
    /// Build from `settings.portfolio` (+ `settings.cobi` for the device
    /// backend; `rt` only for COBI-HLO). `shared` connects this instance
    /// to a fleet-wide cache/metrics pair — pass `None` for a standalone
    /// portfolio with private state.
    pub fn from_settings(
        settings: &Settings,
        seed: u64,
        rt: Option<&ArtifactRuntime>,
        shared: Option<PortfolioShared>,
    ) -> Result<Self> {
        let cfg = &settings.portfolio;
        let policy: RoutePolicy = cfg.policy.parse().map_err(anyhow::Error::msg)?;
        let static_backend = BackendKind::from_name(&cfg.static_backend).with_context(|| {
            format!(
                "unknown portfolio static_backend '{}' \
                 (expected cobi|tabu|sa|greedy|exact|snowball)",
                cfg.static_backend
            )
        })?;
        ensure!(
            (0.0..=1.0).contains(&cfg.epsilon),
            "portfolio epsilon {} outside [0, 1]",
            cfg.epsilon
        );
        let exact_max_n = cfg.exact_max_n.min(EXACT_HARD_CAP);
        // the hardware fault model rides on the internal COBI device:
        // under `[resilience] fault_enabled = true` the portfolio's cobi
        // route degrades exactly like a standalone faulty device, and
        // the bandit's energy-per-spin stats demote it organically
        let mut cobi = CobiDevice::from_config(&settings.cobi, seed ^ 0xF0_1170, rt)?;
        if settings.resilience.fault.enabled {
            cobi.set_fault_model(crate::resilience::FaultModel::new(
                &settings.resilience.fault,
            ));
        }
        Ok(Self {
            policy,
            static_backend,
            epsilon: cfg.epsilon,
            exact_max_n,
            latency_weight: cfg.latency_weight,
            cache_enabled: cfg.cache,
            cobi,
            tabu: TabuSolver::seeded(seed ^ 0x7AB),
            sa: SaSolver::seeded(seed ^ 0x5A),
            greedy: GreedyDescent::new(),
            exact: ExactIsingSolver::new(exact_max_n),
            snowball: SnowballSolver::new(
                seed ^ 0x5B07,
                settings.solvers.snowball.solver_config(),
            ),
            shared: shared.unwrap_or_else(|| PortfolioShared::new(cfg)),
            ledger: None,
            seeds: Pcg32::new(seed, 0x5EED0F),
        })
    }

    /// Attach the fleet energy ledger: every fresh (non-cache-served)
    /// solve is charged to its routed backend under `subsystem`, at the
    /// same committed-dispatch points as the telemetry — cache hits cost
    /// no device time and are never charged.
    pub fn set_ledger(
        &mut self,
        ledger: std::sync::Arc<crate::obs::EnergyLedger>,
        subsystem: crate::obs::Subsystem,
    ) {
        self.ledger = Some((ledger, subsystem));
    }

    /// The shared cache/metrics this portfolio feeds.
    pub fn shared(&self) -> &PortfolioShared {
        &self.shared
    }

    /// Point the internal COBI device's fault-injection counters at a
    /// fleet-shared block (no-op without a fault model).
    pub fn share_fault_counters(
        &mut self,
        counters: std::sync::Arc<crate::resilience::FaultCounters>,
    ) {
        self.cobi.share_fault_counters(counters);
    }

    /// Whether `b` may solve `sample` at all (array limits, enumeration
    /// ceilings); the software heuristics accept anything.
    fn eligible(&self, b: BackendKind, sample: &Ising) -> bool {
        match b {
            BackendKind::Cobi => self.cobi.validate(sample).is_ok(),
            BackendKind::Exact => sample.n <= self.exact_max_n,
            BackendKind::Tabu | BackendKind::Sa | BackendKind::Greedy | BackendKind::Snowball => {
                true
            }
        }
    }

    /// Route one request (all instances of a group share the route; they
    /// are refinement siblings of one window, hence the same size).
    fn choose(&self, sample: &Ising, seed: u64) -> BackendKind {
        let n = sample.n;
        match self.policy {
            // a static exact backend cannot enumerate oversized windows;
            // degrade to Tabu — deterministic (a function of n alone), so
            // the static replay contract is preserved — instead of
            // failing every such request at solve time
            RoutePolicy::Static
                if self.static_backend == BackendKind::Exact && n > self.exact_max_n =>
            {
                BackendKind::Tabu
            }
            RoutePolicy::Static => self.static_backend,
            RoutePolicy::SizeTiered => {
                if n <= self.exact_max_n {
                    BackendKind::Exact
                } else if self.cobi.validate(sample).is_ok() {
                    BackendKind::Cobi
                } else if size_bucket(n) == N_BUCKETS - 1 {
                    // the overflow bucket (beyond every COBI array size):
                    // sharded parallel sweeps win exactly where serial
                    // single-spin scans idle multi-core hosts
                    BackendKind::Snowball
                } else {
                    BackendKind::Tabu
                }
            }
            RoutePolicy::Bandit => {
                let eligible: Vec<BackendKind> = BackendKind::ALL
                    .into_iter()
                    .filter(|&b| self.eligible(b, sample))
                    .collect();
                // tabu/sa/greedy are always eligible, so never empty
                let mut rng = Pcg32::new(seed, BANDIT_STREAM);
                if rng.f64() < self.epsilon {
                    return eligible[rng.below(eligible.len() as u32) as usize];
                }
                let m = self.shared.metrics.lock().unwrap();
                if let Some(&b) = eligible.iter().find(|&&b| m.stats.cell(b, n).count == 0) {
                    return b; // optimism: try unvisited backends first
                }
                eligible
                    .iter()
                    .copied()
                    .min_by(|&a, &b| {
                        let sa = m.stats.score(a, n, self.latency_weight).unwrap();
                        let sb = m.stats.score(b, n, self.latency_weight).unwrap();
                        sa.partial_cmp(&sb).expect("finite bandit scores")
                    })
                    .expect("eligible backends nonempty")
            }
        }
    }

    /// Solve one seeded group: probe the cache, route the remainder.
    /// Returns the results plus this group's telemetry, which the caller
    /// commits only once the WHOLE dispatch has succeeded — a failed
    /// coalesced dispatch is retried per request by the pool, and eager
    /// commits would double-count the groups that had already succeeded
    /// inside the failed dispatch. (Cache inserts stay eager: re-inserting
    /// an identical instance is an in-place update, and a retried group
    /// then exact-hits its own earlier results — same bytes, less work.)
    /// `tag` is the workload tag (0 = legacy/ES): it scopes the cache's
    /// near tiers so a warm hint never crosses workloads (the exact tier
    /// is tag-blind by design — see `cache::WarmStartCache`).
    fn solve_group_inner(
        &mut self,
        g: &SeededGroup<'_>,
        tag: u64,
    ) -> Result<(Vec<SolveResult>, GroupTelemetry)> {
        ensure!(!g.instances.is_empty(), "empty solve group");
        let backend = self.choose(&g.instances[0], g.seed);
        let count = g.instances.len();

        let mut out: Vec<Option<SolveResult>> = vec![None; count];
        // (instance index, optional warm-start hint) still to solve
        let mut todo: Vec<(usize, Option<Vec<i8>>)> = Vec::with_capacity(count);
        if self.cache_enabled {
            for (i, inst) in g.instances.iter().enumerate() {
                match self.shared.cache.lookup_tagged(tag, inst) {
                    CacheOutcome::Exact(r) => out[i] = Some(r),
                    CacheOutcome::Warm(init) => todo.push((i, Some(init))),
                    CacheOutcome::Miss => todo.push((i, None)),
                }
            }
        } else {
            todo.extend((0..count).map(|i| (i, None)));
        }

        let t0 = Instant::now();
        let solved_count = todo.len();
        if !todo.is_empty() {
            match backend {
                BackendKind::Cobi if !self.cache_enabled => {
                    // the PR-1 pool path, bit for bit: one seeded dispatch
                    // over the whole group (the static-policy byte-identity
                    // contract rides on this arm)
                    let res = self
                        .cobi
                        .solve_groups_seeded(&[SeededGroup {
                            instances: g.instances,
                            seed: g.seed,
                        }])?
                        .pop()
                        .expect("one group in, one group out");
                    for (slot, r) in out.iter_mut().zip(res) {
                        *slot = Some(r);
                    }
                }
                BackendKind::Cobi => {
                    for (i, hint) in &todo {
                        let r = self.cobi.solve_seeded_warm(
                            &g.instances[*i],
                            mix(g.seed, *i as u64),
                            hint.as_deref(),
                        )?;
                        out[*i] = Some(r);
                    }
                }
                BackendKind::Tabu => {
                    self.tabu.reseed(g.seed);
                    for (i, hint) in &todo {
                        let inst = &g.instances[*i];
                        out[*i] = Some(match hint {
                            Some(h) => self.tabu.solve_from(inst, h),
                            None => self.tabu.solve(inst),
                        });
                    }
                }
                BackendKind::Sa => {
                    self.sa.reseed(g.seed);
                    for (i, hint) in &todo {
                        let inst = &g.instances[*i];
                        out[*i] = Some(match hint {
                            Some(h) => self.sa.solve_from(inst, h),
                            None => self.sa.solve(inst),
                        });
                    }
                }
                BackendKind::Greedy => {
                    for (i, hint) in &todo {
                        let inst = &g.instances[*i];
                        out[*i] = Some(match hint {
                            Some(h) => self.greedy.solve_from(inst, h),
                            None => self.greedy.solve(inst),
                        });
                    }
                }
                BackendKind::Exact => {
                    for (i, _) in &todo {
                        out[*i] = Some(self.exact.solve_checked(&g.instances[*i])?);
                    }
                }
                BackendKind::Snowball => {
                    self.snowball.reseed(g.seed);
                    for (i, hint) in &todo {
                        let inst = &g.instances[*i];
                        out[*i] = Some(match hint {
                            Some(h) => self.snowball.solve_from(inst, h),
                            None => self.snowball.solve(inst),
                        });
                    }
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();

        if self.cache_enabled {
            for (i, _) in &todo {
                if let Some(r) = &out[*i] {
                    self.shared.cache.insert_tagged(tag, &g.instances[*i], r);
                }
            }
        }

        let mut samples = Vec::with_capacity(solved_count);
        if solved_count > 0 {
            let per_instance = wall / solved_count as f64;
            for (i, _) in &todo {
                if let Some(r) = &out[*i] {
                    let n = g.instances[*i].n;
                    samples.push((n, r.energy / n.max(1) as f64, per_instance));
                }
            }
        }
        let telemetry = GroupTelemetry {
            backend,
            wall: (solved_count > 0).then_some(wall),
            samples,
        };

        let results = out
            .into_iter()
            .map(|r| r.expect("every instance solved or cache-served"))
            .collect();
        Ok((results, telemetry))
    }

    /// Apply the telemetry of a fully successful dispatch to the
    /// fleet-shared metrics (and charge the energy ledger for the fresh
    /// solves — same commit point, same no-double-count-on-retry rule).
    fn commit(&self, deltas: &[GroupTelemetry]) {
        if let Some((ledger, sub)) = &self.ledger {
            for d in deltas {
                ledger.charge_sizes(
                    d.backend.name(),
                    *sub,
                    d.samples.iter().map(|&(n, _, _)| n),
                );
            }
        }
        let mut m = self.shared.metrics.lock().unwrap();
        for d in deltas {
            m.routes[d.backend.index()] += 1;
            if let Some(w) = d.wall {
                m.backend_latency[d.backend.index()].record(w);
            }
            for &(n, energy_per_spin, latency_s) in &d.samples {
                m.stats.record(d.backend, n, energy_per_spin, latency_s);
            }
        }
    }

    /// Solve a single instance under an explicit request seed — the
    /// seeded, `Result`-carrying counterpart of [`IsingSolver::solve`].
    pub fn solve_one(&mut self, ising: &Ising, seed: u64) -> Result<SolveResult> {
        let (mut res, telemetry) = self.solve_group_inner(
            &SeededGroup {
                instances: std::slice::from_ref(ising),
                seed,
            },
            0,
        )?;
        self.commit(std::slice::from_ref(&telemetry));
        Ok(res.pop().expect("one instance in, one result out"))
    }
}

/// Per-group telemetry, buffered until the whole dispatch succeeds (see
/// [`SolverPortfolio::solve_group_inner`]).
struct GroupTelemetry {
    backend: BackendKind,
    /// Wall seconds of the backend dispatch; `None` when every instance
    /// was served from the cache.
    wall: Option<f64>,
    /// (n, energy-per-spin, per-instance latency) per fresh solve.
    samples: Vec<(usize, f64, f64)>,
}

impl PoolSolver for SolverPortfolio {
    fn name(&self) -> &'static str {
        "portfolio"
    }

    fn solve_groups(&mut self, groups: &[SeededGroup<'_>]) -> Result<Vec<Vec<SolveResult>>> {
        let tags = vec![0; groups.len()];
        self.solve_groups_tagged(&tags, groups)
    }

    fn solve_groups_tagged(
        &mut self,
        tags: &[u64],
        groups: &[SeededGroup<'_>],
    ) -> Result<Vec<Vec<SolveResult>>> {
        ensure!(
            tags.len() == groups.len(),
            "tag/group count mismatch: {} vs {}",
            tags.len(),
            groups.len()
        );
        let mut out = Vec::with_capacity(groups.len());
        let mut deltas = Vec::with_capacity(groups.len());
        for (g, &tag) in groups.iter().zip(tags) {
            let (results, telemetry) = self.solve_group_inner(g, tag)?;
            out.push(results);
            deltas.push(telemetry);
        }
        self.commit(&deltas);
        Ok(out)
    }
}

impl IsingSolver for SolverPortfolio {
    fn name(&self) -> &'static str {
        "portfolio"
    }

    fn solve(&mut self, ising: &Ising) -> SolveResult {
        let seed = self.seeds.next_u64();
        self.solve_one(ising, seed)
            .expect("portfolio solve failed (instance not solvable on the routed backend)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cobi::testutil::quantized_glass;
    use crate::corpus::benchmark_set;
    use crate::sched::{doc_seed, summarize_sequential, summarize_with_pool, DevicePool};
    use crate::solvers::exact::ising_ground_exhaustive;

    fn portfolio_settings(policy: &str, backend: &str, cache: bool) -> Settings {
        let mut s = Settings::default();
        s.portfolio.enabled = true;
        s.portfolio.policy = policy.into();
        s.portfolio.static_backend = backend.into();
        s.portfolio.cache = cache;
        s
    }

    fn standalone(policy: &str, backend: &str, cache: bool) -> SolverPortfolio {
        SolverPortfolio::from_settings(&portfolio_settings(policy, backend, cache), 9, None, None)
            .unwrap()
    }

    #[test]
    fn static_cobi_portfolio_is_byte_identical_to_sequential_on_bench_10() {
        // the acceptance pin: `[portfolio] policy = "static"` + cache
        // disabled through the pool == the PR-1 sequential path, byte for
        // byte, on every bench_10 document
        let mut s = portfolio_settings("static", "cobi", false);
        s.pipeline.iterations = 3;
        s.sched.devices = 2;
        let set = benchmark_set("bench_10").unwrap();
        let pool = DevicePool::start(&s, None).unwrap();
        assert_eq!(pool.backend, "portfolio");
        for doc in &set.documents {
            let mut cfg = s.pipeline.clone();
            cfg.summary_len = set.summary_len;
            cfg.seed = doc_seed(cfg.seed, &doc.id);

            let mut client = pool.client(cfg.seed);
            let pooled = summarize_with_pool(doc, &cfg, &mut client).unwrap();

            let mut dev = CobiDevice::from_config(&s.cobi, 0, None).unwrap();
            let sequential = summarize_sequential(doc, &cfg, &mut dev).unwrap();

            assert_eq!(pooled.selected, sequential.selected, "{}", doc.id);
            assert_eq!(pooled.sentences, sequential.sentences, "{}", doc.id);
            assert_eq!(
                pooled.objective.to_bits(),
                sequential.objective.to_bits(),
                "{}",
                doc.id
            );
        }
        let m = pool.portfolio_metrics().expect("portfolio metrics");
        assert_eq!(m.total_routes(), m.route_count(BackendKind::Cobi));
        assert_eq!(m.cache.lookups, 0, "cache must be fully bypassed");
        pool.shutdown();
    }

    #[test]
    fn exact_cache_hits_serve_stored_results() {
        let mut p = standalone("static", "tabu", true);
        let inst = quantized_glass(50, 12);
        let a = p.solve_one(&inst, 7).unwrap();
        let b = p.solve_one(&inst, 7).unwrap();
        assert_eq!(a.spins, b.spins);
        assert_eq!(a.energy, b.energy);
        let m = p.shared().snapshot();
        assert_eq!(m.cache.exact_hits, 1);
        assert_eq!(m.cache.misses, 1);
        assert_eq!(m.cache.entries, 1);
    }

    #[test]
    fn ledger_charges_fresh_solves_but_not_cache_hits() {
        let mut p = standalone("static", "tabu", true);
        let ledger = std::sync::Arc::new(crate::obs::EnergyLedger::new(
            crate::obs::EnergyModel::from_settings(&Settings::default()),
        ));
        p.set_ledger(ledger.clone(), crate::obs::Subsystem::Pool);
        let inst = quantized_glass(55, 12);
        p.solve_one(&inst, 9).unwrap();
        assert_eq!(ledger.totals().solves, 1);
        // identical instance: exact cache hit — no device work, no charge
        p.solve_one(&inst, 10).unwrap();
        assert_eq!(ledger.totals().solves, 1);
        let rows = ledger.rows();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].backend, "tabu", "charged to the ROUTED backend");
        assert_eq!(rows[0].subsystem, "pool");
    }

    #[test]
    fn near_hits_warm_start_same_size_instances() {
        let mut p = standalone("static", "tabu", true);
        let a = quantized_glass(60, 14);
        let b = quantized_glass(61, 14); // same n, different coefficients
        p.solve_one(&a, 1).unwrap();
        let rb = p.solve_one(&b, 2).unwrap();
        assert!((b.energy(&rb.spins) - rb.energy).abs() < 1e-9);
        let m = p.shared().snapshot();
        assert_eq!(m.cache.warm_hits, 1);
        assert_eq!(m.cache.entries, 2);
    }

    #[test]
    fn routed_software_backends_match_the_f64_reference_kernel() {
        // portfolio-routed tabu/greedy solves on quantized instances run
        // the integer kernel; they must equal the f64 reference bit for
        // bit (the portfolio-level face of the kernel equivalence pin)
        let inst = quantized_glass(55, 16);
        let mut p = standalone("static", "tabu", false);
        let routed = p.solve_one(&inst, 0xA11CE).unwrap();
        let mut reference = crate::solvers::tabu::TabuSolver::seeded(0);
        reference.reseed(0xA11CE);
        let expect = reference.solve_reference_f64(&inst);
        assert_eq!(routed.spins, expect.spins);
        assert_eq!(routed.energy.to_bits(), expect.energy.to_bits());

        let mut pg = standalone("static", "greedy", false);
        let routed_g = pg.solve_one(&inst, 0xA11CE).unwrap();
        let expect_g = GreedyDescent::new().solve_reference_f64(&inst);
        assert_eq!(routed_g.spins, expect_g.spins);
        assert_eq!(routed_g.energy.to_bits(), expect_g.energy.to_bits());
    }

    #[test]
    fn size_tiered_routes_tiny_instances_to_exact() {
        let mut p = standalone("size-tiered", "cobi", false);
        let inst = quantized_glass(70, 10);
        let r = p.solve_one(&inst, 3).unwrap();
        let (ground, _, _) = ising_ground_exhaustive(&inst);
        assert!((r.energy - ground).abs() < 1e-9, "exact route must be optimal");
        let m = p.shared().snapshot();
        assert_eq!(m.route_count(BackendKind::Exact), 1);
        assert_eq!(m.total_routes(), 1);
    }

    #[test]
    fn size_tiered_routes_chip_sized_instances_to_cobi() {
        let mut p = standalone("size-tiered", "cobi", false);
        let inst = quantized_glass(71, 24); // > exact_max_n, <= 59 spins
        p.solve_one(&inst, 4).unwrap();
        assert_eq!(p.shared().snapshot().route_count(BackendKind::Cobi), 1);
    }

    #[test]
    fn size_tiered_routes_the_overflow_bucket_to_snowball() {
        // beyond every COBI array size AND past the last bandit size
        // bound: the sharded parallel-spin backend owns this bucket
        let mut p = standalone("size-tiered", "cobi", false);
        let inst = quantized_glass(74, 70); // > 64 -> overflow bucket
        let r = p.solve_one(&inst, 5).unwrap();
        assert!((inst.energy(&r.spins) - r.energy).abs() < 1e-9);
        let m = p.shared().snapshot();
        assert_eq!(m.route_count(BackendKind::Snowball), 1);
        assert_eq!(m.total_routes(), 1);
    }

    #[test]
    fn static_snowball_portfolio_replays_the_direct_solver() {
        // a statically-routed snowball solve is byte-identical to driving
        // the solver directly with the same reseed — the same replay
        // contract the tabu/sa arms carry
        let inst = quantized_glass(75, 18);
        let mut p = standalone("static", "snowball", false);
        let routed = p.solve_one(&inst, 0xBEEF).unwrap();
        let mut direct = SnowballSolver::seeded(9 ^ 0x5B07);
        direct.reseed(0xBEEF);
        let expect = direct.solve(&inst);
        assert_eq!(routed.spins, expect.spins);
        assert_eq!(routed.energy.to_bits(), expect.energy.to_bits());
        assert_eq!(
            p.shared().snapshot().route_count(BackendKind::Snowball),
            1
        );
    }

    #[test]
    fn static_exact_degrades_to_tabu_on_oversized_windows() {
        // static_backend = "exact" must not fail every P=20 window at
        // solve time: oversized instances route to tabu deterministically
        let mut p = standalone("static", "exact", false);
        let small = quantized_glass(72, 10);
        let big = quantized_glass(73, 24); // > exact_max_n
        p.solve_one(&small, 1).unwrap();
        p.solve_one(&big, 2).unwrap();
        let m = p.shared().snapshot();
        assert_eq!(m.route_count(BackendKind::Exact), 1);
        assert_eq!(m.route_count(BackendKind::Tabu), 1);
    }

    #[test]
    fn bandit_routing_is_deterministic_given_seeds() {
        let run = || {
            let mut s = portfolio_settings("bandit", "cobi", false);
            s.portfolio.epsilon = 0.3;
            let mut p = SolverPortfolio::from_settings(&s, 9, None, None).unwrap();
            let mut spins = Vec::new();
            for k in 0..8u64 {
                let inst = quantized_glass(80 + k, 12);
                spins.push(p.solve_one(&inst, 1000 + k).unwrap().spins);
            }
            (spins, p.shared().snapshot().routes)
        };
        let (spins_a, routes_a) = run();
        let (spins_b, routes_b) = run();
        assert_eq!(spins_a, spins_b);
        assert_eq!(routes_a, routes_b);
        // eight requests were routed somewhere
        assert_eq!(routes_a.iter().sum::<u64>(), 8);
    }

    #[test]
    fn pool_devices_share_one_fleet_wide_cache() {
        let mut s = portfolio_settings("static", "cobi", true);
        s.sched.devices = 2;
        let pool = DevicePool::start(&s, None).unwrap();
        let instances: Vec<Ising> = (0..4).map(|k| quantized_glass(90 + k, 12)).collect();
        let fresh: Vec<Ising> = (0..4).map(|k| quantized_glass(190 + k, 12)).collect();
        let mut client = pool.client(0xCAFE);
        // first request populates the cache...
        client.submit(instances.clone()).unwrap().wait().unwrap();
        // ...an identical request exact-hits it, whichever device serves...
        client.submit(instances.clone()).unwrap().wait().unwrap();
        // ...and distinct same-size instances warm-hit the near tier
        client.submit(fresh).unwrap().wait().unwrap();
        drop(client);
        let m = pool.portfolio_metrics().expect("portfolio metrics");
        assert_eq!(m.cache.exact_hits, 4);
        assert_eq!(m.cache.warm_hits, 4);
        pool.shutdown();
    }

    #[test]
    fn faulty_cobi_degrades_the_bandit_quality_signal() {
        // the demotion mechanism: a faulty device records worse
        // energy-per-spin into its bandit cell than a clean one on the
        // same workload, so the exploit choice steers away from it.
        // Static-routed to cobi so every sample lands in the cobi cell.
        let run = |faulty: bool| {
            let mut s = portfolio_settings("static", "cobi", false);
            if faulty {
                s.resilience.fault.enabled = true;
                s.resilience.fault.stuck_rate = 0.4;
                s.resilience.fault.drift_rate = 0.2;
            }
            let mut p = SolverPortfolio::from_settings(&s, 9, None, None).unwrap();
            for k in 0..12u64 {
                let inst = quantized_glass(700 + k, 14);
                p.solve_one(&inst, 4000 + k).unwrap();
            }
            p.shared()
                .snapshot()
                .stats
                .cell(BackendKind::Cobi, 14)
                .mean_energy_per_spin()
        };
        let clean = run(false);
        let degraded = run(true);
        assert!(
            degraded > clean,
            "faulty cobi quality signal {degraded} must be worse (higher) than clean {clean}"
        );
    }

    #[test]
    fn rejects_bad_configuration() {
        let mut s = portfolio_settings("static", "cobi", false);
        s.portfolio.policy = "alphazero".into();
        assert!(SolverPortfolio::from_settings(&s, 1, None, None).is_err());
        let mut s = portfolio_settings("static", "gurobi", false);
        s.portfolio.static_backend = "gurobi".into();
        assert!(SolverPortfolio::from_settings(&s, 1, None, None).is_err());
        let mut s = portfolio_settings("bandit", "cobi", false);
        s.portfolio.epsilon = 1.5;
        assert!(SolverPortfolio::from_settings(&s, 1, None, None).is_err());
    }
}
