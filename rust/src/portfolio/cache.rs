//! Fleet-wide warm-start cache keyed by structural fingerprints of the
//! quantized Ising instance.
//!
//! Three lookup tiers, tried in order:
//!
//! 1. **exact** — FNV-1a over (n, every h/J bit pattern), verified by full
//!    instance equality so hash collisions can never serve wrong results.
//!    A hit returns the stored solution directly: zero device time.
//! 2. **near (fine)** — (n, sign class of every h). Stochastic rounding
//!    re-samples coefficient magnitudes between refinement iterations but
//!    rarely flips field signs, so sibling Hamiltonians of the same window
//!    land on the same fine key. A hit serves the stored spins as an
//!    initial configuration for a warm-started solver
//!    (`IsingSolver::solve_from`, or phase initialisation on COBI).
//! 3. **near (coarse)** — n alone: the most recent same-size solution. A
//!    weak prior, but a free one — the solver still anneals from it.
//!
//! Capacity is bounded; eviction is insertion-order (FIFO), which matches
//! the repeated-document workload the cache targets: hot entries are
//! re-inserted by their next miss after eviction. Shared across all pool
//! devices behind an `Arc` — reuse is fleet-wide, not per-device
//! (DESIGN.md decision #10/#11).

use std::collections::{HashMap, VecDeque};
use std::sync::Mutex;

use crate::ising::Ising;
use crate::solvers::SolveResult;

/// Result of one cache probe.
#[derive(Debug, Clone)]
pub enum CacheOutcome {
    /// Identical quantized instance seen before: the stored solution,
    /// servable without any solve.
    Exact(SolveResult),
    /// Structurally similar instance seen before: stored spins to use as
    /// a warm-start hint (length always equals the probed instance's n).
    Warm(Vec<i8>),
    /// Nothing usable cached.
    Miss,
}

/// Cache counters, snapshotted into
/// [`PortfolioMetrics`](super::PortfolioMetrics).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CacheStats {
    /// Cache probes.
    pub lookups: u64,
    /// Exact-tier hits (stored solution served, zero device time).
    pub exact_hits: u64,
    /// Near-tier hits (warm-start hint served).
    pub warm_hits: u64,
    /// Probes that found nothing usable.
    pub misses: u64,
    /// Solutions stored.
    pub inserts: u64,
    /// FIFO evictions.
    pub evictions: u64,
    /// Entries currently held.
    pub entries: usize,
}

impl CacheStats {
    /// Exact hits per lookup.
    pub fn exact_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.exact_hits as f64 / self.lookups as f64
        }
    }

    /// Warm hits per lookup.
    pub fn warm_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.warm_hits as f64 / self.lookups as f64
        }
    }

    /// One-line counter summary.
    pub fn report(&self) -> String {
        format!(
            "cache lookups={} exact={:.0}% warm={:.0}% entries={} evictions={}",
            self.lookups,
            self.exact_rate() * 100.0,
            self.warm_rate() * 100.0,
            self.entries,
            self.evictions,
        )
    }
}

struct Entry {
    // keys are stored so eviction can clean the indices in O(1)
    exact_key: u64,
    fine_key: u64,
    /// Workload tag the entry was inserted under (0 = legacy/ES). Near
    /// tiers are scoped by it; the exact tier deliberately is not.
    tag: u64,
    ising: Ising,
    spins: Vec<i8>,
    energy: f64,
}

#[derive(Default)]
struct Inner {
    stats: CacheStats,
    entries: HashMap<u64, Entry>,
    /// exact_key -> entry ids (collision chain; equality-verified).
    by_exact: HashMap<u64, Vec<u64>>,
    /// fine near key (workload tag + n + h sign classes) -> most recent
    /// entry id.
    by_fine: HashMap<u64, u64>,
    /// (workload tag, n) -> most recent entry id.
    by_size: HashMap<(u64, usize), u64>,
    /// Insertion order for FIFO eviction.
    order: VecDeque<u64>,
    next_id: u64,
}

/// Bounded, thread-safe warm-start cache (see module docs).
pub struct WarmStartCache {
    inner: Mutex<Inner>,
    capacity: usize,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
/// Mixed into value-hashed words so an integer coefficient and a raw bit
/// pattern of the same numeric value cannot trivially alias (collisions
/// are harmless anyway — exact hits verify full equality).
const INT_TAG: u64 = 0x51A0_7E11_0000_0000;

/// FNV-1a over one u64, fed byte by byte (matches `fnv1a` on the word's
/// LE bytes) — lets the keys stream without building a byte buffer.
#[inline]
fn fnv_u64(mut hash: u64, word: u64) -> u64 {
    for b in word.to_le_bytes() {
        hash ^= b as u64;
        hash = hash.wrapping_mul(FNV_PRIME);
    }
    hash
}

/// Exact structural fingerprint of the quantized instance.
///
/// Every integer-valued coefficient — i.e. every coefficient of every
/// quantized instance, which is all the cache ever sees in production —
/// hashes by its **integer value** (`v as i64`), not its `f32` bit
/// pattern. That is faster (a cast instead of byte serialization through
/// an intermediate `Vec`, which this function no longer allocates) and
/// removes float-bit fragility: `-0.0` and `+0.0` compare equal under
/// `Ising` equality but have different bits, so bit-hashing could miss an
/// entry that full equality would serve. Fractional or out-of-range
/// coefficients fall back to bit-pattern hashing; a hash collision can
/// only ever cost a redundant solve because hits verify full equality
/// (DESIGN.md decision #10).
pub fn exact_key(ising: &Ising) -> u64 {
    let mut hash = fnv_u64(FNV_OFFSET, ising.n as u64);
    for &v in ising.h.iter().chain(ising.j.iter()) {
        let word = if v.is_finite() && v.fract() == 0.0 && v.abs() <= 1e9 {
            (v as i64 as u64) ^ INT_TAG
        } else {
            v.to_bits() as u64
        };
        hash = fnv_u64(hash, word);
    }
    hash
}

/// Fine near key: the workload tag, then n, then the sign class
/// (-, 0, +) of every local field. The tag is mixed FIRST so two
/// workloads whose instances share (n, sign pattern) — common, since
/// every improved-formulation k-of-n instance has an all-negative h —
/// can never serve each other warm hints. Streams like [`exact_key`] —
/// no byte buffer.
fn fine_key(tag: u64, ising: &Ising) -> u64 {
    let mut hash = fnv_u64(FNV_OFFSET, tag);
    hash = fnv_u64(hash, ising.n as u64);
    for &v in &ising.h {
        let class: u64 = if v > 0.0 {
            1
        } else if v < 0.0 {
            2
        } else {
            0
        };
        hash = fnv_u64(hash, class);
    }
    hash
}

impl WarmStartCache {
    /// A cache holding at most `capacity` solved instances.
    pub fn new(capacity: usize) -> Self {
        Self {
            inner: Mutex::new(Inner::default()),
            capacity: capacity.max(1),
        }
    }

    /// Probe the cache for `ising` under the legacy/ES namespace
    /// (workload tag 0) — see [`lookup_tagged`](WarmStartCache::lookup_tagged).
    pub fn lookup(&self, ising: &Ising) -> CacheOutcome {
        self.lookup_tagged(0, ising)
    }

    /// Probe the cache for `ising` under workload namespace `tag` (see
    /// module docs for the tier order). The exact tier is deliberately
    /// tag-blind: an identical quantized instance has an identical ground
    /// truth regardless of which workload produced it, so serving it
    /// across workloads is correct and free. The near tiers are scoped by
    /// `tag`: a warm hint is only a prior, and a prior from a different
    /// workload's energy landscape is cross-contamination, not reuse.
    pub fn lookup_tagged(&self, tag: u64, ising: &Ising) -> CacheOutcome {
        let mut guard = self.inner.lock().unwrap();
        // reborrow once so field borrows are precise (stats counters are
        // bumped while sibling indices are still borrowed)
        let inner = &mut *guard;
        inner.stats.lookups += 1;
        let ek = exact_key(ising);
        if let Some(ids) = inner.by_exact.get(&ek) {
            for id in ids {
                let e = &inner.entries[id];
                if e.ising == *ising {
                    let result = SolveResult {
                        spins: e.spins.clone(),
                        energy: e.energy,
                    };
                    inner.stats.exact_hits += 1;
                    return CacheOutcome::Exact(result);
                }
            }
        }
        for id in [
            inner.by_fine.get(&fine_key(tag, ising)).copied(),
            inner.by_size.get(&(tag, ising.n)).copied(),
        ]
        .into_iter()
        .flatten()
        {
            let e = &inner.entries[&id];
            if e.tag == tag && e.ising.n == ising.n {
                let spins = e.spins.clone();
                inner.stats.warm_hits += 1;
                return CacheOutcome::Warm(spins);
            }
        }
        inner.stats.misses += 1;
        CacheOutcome::Miss
    }

    /// Record a solved instance under the legacy/ES namespace (workload
    /// tag 0) — see [`insert_tagged`](WarmStartCache::insert_tagged).
    pub fn insert(&self, ising: &Ising, result: &SolveResult) {
        self.insert_tagged(0, ising, result);
    }

    /// Record a solved instance under workload namespace `tag`.
    /// Re-inserting an identical instance keeps the lower-energy solution
    /// (and adopts `tag` for its near-tier scope); otherwise the oldest
    /// entry is evicted once the capacity bound is reached.
    pub fn insert_tagged(&self, tag: u64, ising: &Ising, result: &SolveResult) {
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        let ek = exact_key(ising);
        let fk = fine_key(tag, ising);
        let existing = inner
            .by_exact
            .get(&ek)
            .and_then(|ids| ids.iter().copied().find(|id| inner.entries[id].ising == *ising));
        if let Some(id) = existing {
            let e = inner.entries.get_mut(&id).unwrap();
            if result.energy < e.energy {
                e.spins = result.spins.clone();
                e.energy = result.energy;
            }
            // adopt the inserting workload's namespace and refresh the
            // recency of its near indices (the stale-tag indices still
            // point at a valid same-tag entry or get overwritten later)
            e.tag = tag;
            e.fine_key = fk;
            inner.by_fine.insert(fk, id);
            inner.by_size.insert((tag, ising.n), id);
            return;
        }
        while inner.entries.len() >= self.capacity {
            let Some(old) = inner.order.pop_front() else {
                break;
            };
            if let Some(e) = inner.entries.remove(&old) {
                if let Some(chain) = inner.by_exact.get_mut(&e.exact_key) {
                    chain.retain(|&id| id != old);
                    if chain.is_empty() {
                        inner.by_exact.remove(&e.exact_key);
                    }
                }
                // near indices may already point at a newer entry with
                // the same key — drop them only if they point at us
                if inner.by_fine.get(&e.fine_key) == Some(&old) {
                    inner.by_fine.remove(&e.fine_key);
                }
                if inner.by_size.get(&(e.tag, e.ising.n)) == Some(&old) {
                    inner.by_size.remove(&(e.tag, e.ising.n));
                }
                inner.stats.evictions += 1;
            }
        }
        let id = inner.next_id;
        inner.next_id += 1;
        inner.entries.insert(
            id,
            Entry {
                exact_key: ek,
                fine_key: fk,
                tag,
                ising: ising.clone(),
                spins: result.spins.clone(),
                energy: result.energy,
            },
        );
        inner.by_exact.entry(ek).or_default().push(id);
        inner.by_fine.insert(fk, id);
        inner.by_size.insert((tag, ising.n), id);
        inner.order.push_back(id);
        inner.stats.inserts += 1;
    }

    /// Counter snapshot (entries reflects the current fill level).
    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.lock().unwrap();
        let mut s = inner.stats.clone();
        s.entries = inner.entries.len();
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn glass(seed: u64, n: usize) -> Ising {
        crate::cobi::testutil::quantized_glass(seed, n)
    }

    fn solved(spins: Vec<i8>, energy: f64) -> SolveResult {
        SolveResult { spins, energy }
    }

    #[test]
    fn exact_hit_round_trips_the_stored_solution() {
        let cache = WarmStartCache::new(16);
        let inst = glass(1, 10);
        assert!(matches!(cache.lookup(&inst), CacheOutcome::Miss));
        let r = solved(vec![1; 10], -5.0);
        cache.insert(&inst, &r);
        match cache.lookup(&inst) {
            CacheOutcome::Exact(hit) => {
                assert_eq!(hit.spins, r.spins);
                assert_eq!(hit.energy, r.energy);
            }
            other => panic!("expected exact hit, got {other:?}"),
        }
        let s = cache.stats();
        assert_eq!((s.lookups, s.exact_hits, s.misses), (2, 1, 1));
    }

    #[test]
    fn same_size_instances_serve_warm_hints() {
        let cache = WarmStartCache::new(16);
        let a = glass(2, 12);
        let b = glass(3, 12); // distinct coefficients, same n
        assert_ne!(a, b);
        cache.insert(&a, &solved(vec![-1; 12], -1.0));
        match cache.lookup(&b) {
            CacheOutcome::Warm(init) => assert_eq!(init.len(), 12),
            other => panic!("expected warm hit, got {other:?}"),
        }
        // a different size misses entirely
        assert!(matches!(cache.lookup(&glass(4, 9)), CacheOutcome::Miss));
        let s = cache.stats();
        assert_eq!(s.warm_hits, 1);
        assert_eq!(s.misses, 1);
    }

    #[test]
    fn coefficient_changes_change_the_exact_key() {
        let a = glass(5, 8);
        let mut b = a.clone();
        b.h[0] += 1.0;
        assert_ne!(exact_key(&a), exact_key(&b));
        assert_ne!(exact_key(&a), exact_key(&glass(5, 9)));
    }

    #[test]
    fn exact_key_hashes_integer_values_not_float_bits() {
        // -0.0 == +0.0 under Ising equality: the integer-tuple key must
        // agree, so an entry stored under one zero is servable under the
        // other (the float-bit fragility the integer key retires)
        let a = glass(7, 8);
        let mut b = a.clone();
        for v in b.h.iter_mut().chain(b.j.iter_mut()) {
            if *v == 0.0 {
                *v = -0.0;
            }
        }
        assert_eq!(a, b, "instances must be equal despite different zero bits");
        assert_eq!(exact_key(&a), exact_key(&b));

        let cache = WarmStartCache::new(8);
        cache.insert(&a, &solved(vec![1; 8], -3.0));
        assert!(matches!(cache.lookup(&b), CacheOutcome::Exact(_)));
    }

    #[test]
    fn fractional_instances_still_key_consistently() {
        let mut a = glass(8, 6);
        a.h[0] = 0.25; // not integer-valued: bit-pattern fallback
        let cache = WarmStartCache::new(8);
        cache.insert(&a, &solved(vec![-1; 6], -1.5));
        assert!(matches!(cache.lookup(&a), CacheOutcome::Exact(_)));
    }

    #[test]
    fn reinsert_keeps_the_better_solution() {
        let cache = WarmStartCache::new(4);
        let inst = glass(6, 10);
        cache.insert(&inst, &solved(vec![1; 10], -2.0));
        cache.insert(&inst, &solved(vec![-1; 10], -7.0)); // better: kept
        cache.insert(&inst, &solved(vec![1; 10], -3.0)); // worse: ignored
        match cache.lookup(&inst) {
            CacheOutcome::Exact(hit) => {
                assert_eq!(hit.energy, -7.0);
                assert_eq!(hit.spins, vec![-1; 10]);
            }
            other => panic!("expected exact hit, got {other:?}"),
        }
        assert_eq!(cache.stats().entries, 1);
    }

    #[test]
    fn faulty_retried_dispatch_cannot_poison_the_exact_tier() {
        // the resilience scenario: a request solved cleanly populates
        // the exact tier; a later faulty/retried dispatch of the SAME
        // quantized instance produces a worse-energy solution and
        // re-inserts it. The insert-only-if-better guard must keep the
        // good solution — retried dispatches can add work, never degrade
        // what the fleet already knows.
        let cache = WarmStartCache::new(8);
        let inst = glass(20, 12);
        let good = solved(vec![-1; 12], -9.0);
        cache.insert(&inst, &good);
        // a degraded re-solve (e.g. stuck oscillators) lands higher
        cache.insert(&inst, &solved(vec![1; 12], -2.0));
        // and a retry burst re-inserts several bad candidates
        for k in 0..3 {
            cache.insert(&inst, &solved(vec![1; 12], -1.0 - k as f64));
        }
        match cache.lookup(&inst) {
            CacheOutcome::Exact(hit) => {
                assert_eq!(hit.energy, -9.0, "worse-energy reinsert poisoned the cache");
                assert_eq!(hit.spins, good.spins);
            }
            other => panic!("expected exact hit, got {other:?}"),
        }
        let s = cache.stats();
        assert_eq!(s.entries, 1, "reinserts must update in place, not duplicate");
        assert_eq!(s.evictions, 0);
    }

    #[test]
    fn capacity_is_bounded_with_fifo_eviction() {
        let cache = WarmStartCache::new(2);
        let a = glass(10, 8);
        let b = glass(11, 8);
        let c = glass(12, 8);
        for inst in [&a, &b, &c] {
            cache.insert(inst, &solved(vec![1; 8], 0.0));
        }
        let s = cache.stats();
        assert_eq!(s.entries, 2);
        assert_eq!(s.evictions, 1);
        // oldest (a) evicted; newest (c) still exactly servable
        assert!(matches!(cache.lookup(&c), CacheOutcome::Exact(_)));
        // a now only warm-hits via the survivors' near keys
        assert!(!matches!(cache.lookup(&a), CacheOutcome::Exact(_)));
    }

    #[test]
    fn near_tiers_are_scoped_per_workload_tag() {
        // the cross-workload poisoning regression: two workloads with
        // identical instance sizes (and identical all-negative h sign
        // patterns, the improved formulation's shape) must never serve
        // each other warm hints — only the equality-verified exact tier
        // may cross tags
        const ES: u64 = 0;
        const RETRIEVAL: u64 = 0x1234_5678_9ABC_DEF0;
        let cache = WarmStartCache::new(16);
        let a = glass(30, 12);
        let b = glass(31, 12); // same n, different coefficients
        cache.insert_tagged(RETRIEVAL, &a, &solved(vec![-1; 12], -4.0));

        // same tag, same n: warm hint served
        assert!(matches!(cache.lookup_tagged(RETRIEVAL, &b), CacheOutcome::Warm(_)));
        // other tag, same n: MISS — no cross-workload hint
        assert!(matches!(cache.lookup_tagged(ES, &b), CacheOutcome::Miss));

        // identical instance: exact tier serves across tags (same
        // quantized Hamiltonian ⇒ same ground truth, tag-independent)
        assert!(matches!(cache.lookup_tagged(ES, &a), CacheOutcome::Exact(_)));

        // and the reverse direction: an ES entry never warms retrieval
        let c = glass(32, 14);
        let d = glass(33, 14);
        cache.insert(&c, &solved(vec![1; 14], -2.0)); // legacy insert = tag 0
        assert!(matches!(cache.lookup(&d), CacheOutcome::Warm(_)));
        assert!(matches!(cache.lookup_tagged(RETRIEVAL, &d), CacheOutcome::Miss));
    }

    #[test]
    fn tag_scoped_eviction_cleans_the_right_indices() {
        const TAG: u64 = 77;
        let cache = WarmStartCache::new(2);
        let a = glass(40, 8);
        let b = glass(41, 8);
        let c = glass(42, 8);
        cache.insert_tagged(TAG, &a, &solved(vec![1; 8], 0.0));
        cache.insert_tagged(TAG, &b, &solved(vec![1; 8], 0.0));
        cache.insert_tagged(TAG, &c, &solved(vec![1; 8], 0.0)); // evicts a
        let s = cache.stats();
        assert_eq!((s.entries, s.evictions), (2, 1));
        // survivors still serve their own tag, and only their own tag
        assert!(matches!(cache.lookup_tagged(TAG, &a), CacheOutcome::Warm(_)));
        assert!(matches!(cache.lookup_tagged(0, &a), CacheOutcome::Miss));
    }

    #[test]
    fn stats_rates_are_sane() {
        let s = CacheStats::default();
        assert_eq!(s.exact_rate(), 0.0);
        assert_eq!(s.warm_rate(), 0.0);
        assert!(s.report().contains("lookups=0"));
    }
}
