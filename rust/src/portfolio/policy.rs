//! Routing policies: which backend solves a given subproblem.
//!
//! Three policies, selected by `[portfolio] policy`:
//!
//! * `static` — every request goes to one configured backend. This is the
//!   determinism-preserving mode: with the warm-start cache disabled it is
//!   byte-identical to hosting that backend directly on the pool.
//! * `size-tiered` — route by instance size: tiny instances go to the
//!   exhaustive exact solver (cheaper than annealing and provably
//!   optimal), chip-sized instances to COBI, the largest bucket to the
//!   sharded parallel-spin Snowball backend (multi-core wins exactly
//!   where serial sweeps idle), and the rest to Tabu. The shape the
//!   paper's own evaluation suggests (Fig. 7/8: the best solver depends
//!   on subproblem size).
//! * `bandit` — epsilon-greedy over per-(backend, size-bucket) running
//!   quality/latency statistics updated online, so the fleet learns which
//!   backend wins for which workload. Exploration draws derive from the
//!   request seed, so routing is deterministic given the document seed
//!   (though results still depend on fleet history through the stats).

use std::str::FromStr;

/// Every backend a [`SolverPortfolio`](super::SolverPortfolio) can route
/// to, in fixed preference order (used to break bandit score ties and to
/// order "never tried" exploration).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// The simulated COBI oscillator device (native or HLO backend).
    Cobi,
    /// Tabu search (the paper's software baseline).
    Tabu,
    /// Simulated annealing.
    Sa,
    /// Deterministic steepest-descent (fast, hint-friendly).
    Greedy,
    /// Exhaustive ground-state enumeration for tiny N.
    Exact,
    /// Snowball-style sharded parallel-spin MCMC (multi-core large-n).
    Snowball,
}

impl BackendKind {
    /// All backends, in the canonical routing/tie-break order.
    pub const ALL: [BackendKind; 6] = [
        BackendKind::Cobi,
        BackendKind::Tabu,
        BackendKind::Sa,
        BackendKind::Greedy,
        BackendKind::Exact,
        BackendKind::Snowball,
    ];

    /// Number of backends (array dimension for per-backend counters).
    pub const COUNT: usize = Self::ALL.len();

    /// Canonical lowercase name.
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Cobi => "cobi",
            BackendKind::Tabu => "tabu",
            BackendKind::Sa => "sa",
            BackendKind::Greedy => "greedy",
            BackendKind::Exact => "exact",
            BackendKind::Snowball => "snowball",
        }
    }

    /// Stable index into per-backend counter arrays.
    pub fn index(self) -> usize {
        match self {
            BackendKind::Cobi => 0,
            BackendKind::Tabu => 1,
            BackendKind::Sa => 2,
            BackendKind::Greedy => 3,
            BackendKind::Exact => 4,
            BackendKind::Snowball => 5,
        }
    }

    /// Parse a canonical name (`None` on junk).
    pub fn from_name(s: &str) -> Option<Self> {
        Self::ALL.into_iter().find(|b| b.name() == s)
    }
}

/// Routing policy (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Everything to the configured static backend.
    Static,
    /// Route by instance size: exact for tiny, COBI for chip-sized,
    /// Tabu for the rest.
    SizeTiered,
    /// Epsilon-greedy over per-(backend, size-bucket) running stats.
    Bandit,
}

impl FromStr for RoutePolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "static" => Ok(RoutePolicy::Static),
            "size-tiered" => Ok(RoutePolicy::SizeTiered),
            "bandit" => Ok(RoutePolicy::Bandit),
            other => Err(format!(
                "unknown portfolio policy '{other}' (expected static|size-tiered|bandit)"
            )),
        }
    }
}

/// Upper bounds of the bandit size buckets (spin counts); one overflow
/// bucket past the last bound. Chosen to straddle the decomposition's
/// window sizes (P=20, Q=10, final M) and the 59-spin COBI array.
pub const SIZE_BOUNDS: [usize; 4] = [8, 16, 32, 64];

/// Bucket count, including the overflow bucket.
pub const N_BUCKETS: usize = SIZE_BOUNDS.len() + 1;

/// Bucket index for an `n`-spin instance.
pub fn size_bucket(n: usize) -> usize {
    SIZE_BOUNDS
        .iter()
        .position(|&b| n <= b)
        .unwrap_or(SIZE_BOUNDS.len())
}

/// Running statistics for one (backend, size-bucket) cell.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CellStats {
    /// Instances solved by this backend in this bucket.
    pub count: u64,
    /// Sum of per-instance `energy / n` (lower is better quality).
    pub energy_per_spin_sum: f64,
    /// Sum of per-instance wall-clock seconds.
    pub latency_sum_s: f64,
}

impl CellStats {
    /// Mean solution energy per spin (quality proxy; lower is better).
    pub fn mean_energy_per_spin(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.energy_per_spin_sum / self.count as f64
        }
    }

    /// Mean wall-clock per request, seconds.
    pub fn mean_latency_s(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.latency_sum_s / self.count as f64
        }
    }
}

/// Per-(backend, size-bucket) online statistics driving the bandit policy.
///
/// Quality is tracked as mean energy per spin: instances inside one bucket
/// share the quantization grid (integer ±`weight_range`) and similar n, so
/// the per-spin energies of competing backends are directly comparable —
/// a cheap stand-in for the paper's TTS curves that needs no per-instance
/// ground truth.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BanditStats {
    cells: [[CellStats; N_BUCKETS]; BackendKind::COUNT],
}

impl BanditStats {
    /// Fold one request's outcome into its (backend, size) cell.
    pub fn record(&mut self, b: BackendKind, n: usize, energy_per_spin: f64, latency_s: f64) {
        let c = &mut self.cells[b.index()][size_bucket(n)];
        c.count += 1;
        c.energy_per_spin_sum += energy_per_spin;
        c.latency_sum_s += latency_s;
    }

    /// The running stats cell for (backend, size bucket).
    pub fn cell(&self, b: BackendKind, n: usize) -> &CellStats {
        &self.cells[b.index()][size_bucket(n)]
    }

    /// Exploitation score for backend `b` on `n`-spin instances — lower is
    /// better. `None` until the cell has data (the bandit tries unvisited
    /// backends first, in [`BackendKind::ALL`] order).
    pub fn score(&self, b: BackendKind, n: usize, latency_weight: f64) -> Option<f64> {
        let c = self.cell(b, n);
        (c.count > 0).then(|| c.mean_energy_per_spin() + latency_weight * c.mean_latency_s())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_names_round_trip() {
        for b in BackendKind::ALL {
            assert_eq!(BackendKind::from_name(b.name()), Some(b));
        }
        assert_eq!(BackendKind::from_name("gurobi"), None);
        // indices are a permutation of 0..COUNT
        let mut seen = [false; BackendKind::COUNT];
        for b in BackendKind::ALL {
            assert!(!seen[b.index()]);
            seen[b.index()] = true;
        }
    }

    #[test]
    fn policies_parse() {
        assert_eq!("static".parse::<RoutePolicy>().unwrap(), RoutePolicy::Static);
        assert_eq!(
            "size-tiered".parse::<RoutePolicy>().unwrap(),
            RoutePolicy::SizeTiered
        );
        assert_eq!("bandit".parse::<RoutePolicy>().unwrap(), RoutePolicy::Bandit);
        assert!("greedy-epsilon".parse::<RoutePolicy>().is_err());
    }

    #[test]
    fn buckets_cover_all_sizes() {
        assert_eq!(size_bucket(1), 0);
        assert_eq!(size_bucket(8), 0);
        assert_eq!(size_bucket(9), 1);
        assert_eq!(size_bucket(20), 2);
        assert_eq!(size_bucket(64), 3);
        assert_eq!(size_bucket(100), 4);
        assert!(size_bucket(usize::MAX) < N_BUCKETS);
    }

    #[test]
    fn bandit_stats_accumulate_and_score() {
        let mut s = BanditStats::default();
        assert!(s.score(BackendKind::Tabu, 10, 1.0).is_none());
        s.record(BackendKind::Tabu, 10, -2.0, 0.010);
        s.record(BackendKind::Tabu, 10, -4.0, 0.030);
        let c = s.cell(BackendKind::Tabu, 10);
        assert_eq!(c.count, 2);
        assert!((c.mean_energy_per_spin() + 3.0).abs() < 1e-12);
        assert!((c.mean_latency_s() - 0.020).abs() < 1e-12);
        let score = s.score(BackendKind::Tabu, 10, 1.0).unwrap();
        assert!((score - (-3.0 + 0.020)).abs() < 1e-12);
        // other cells untouched
        assert!(s.score(BackendKind::Tabu, 40, 1.0).is_none());
        assert!(s.score(BackendKind::Cobi, 10, 1.0).is_none());
    }
}
