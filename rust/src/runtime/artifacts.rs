//! Artifact registry: manifest-driven load/compile/execute of the AOT
//! HLO-text modules emitted by `python -m compile.aot`.
//!
//! Compilation happens once per artifact (lazily, cached); execution takes
//! and returns flat f32/i32 buffers so the rest of L3 never touches xla
//! types. The manifest's static shapes are validated on every call —
//! shape drift between the Python constants and the Rust callers is a
//! build error, not a silent miscomputation.
//!
//! Feature gating (DESIGN.md §Substitutions): the PJRT execution backend
//! needs the `xla` bindings crate, which is not part of the default
//! (offline) crate set. Without `--features pjrt` the registry compiles to
//! a stub whose `open()` fails with a descriptive error, so every caller
//! degrades to the native backends at runtime instead of failing to build.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

/// One input/output slot from the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct SlotSpec {
    /// Element type ("f32" / "i32").
    pub dtype: String,
    /// Static shape.
    pub dims: Vec<usize>,
}

impl SlotSpec {
    /// Element count (product of dims).
    pub fn elements(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }
}

/// Manifest entry for one graph.
#[derive(Debug, Clone, Default)]
pub struct GraphSpec {
    /// Artifact file name within the directory.
    pub file: String,
    /// Input slot specs, in call order.
    pub inputs: Vec<SlotSpec>,
    /// Output slot specs.
    pub outputs: Vec<SlotSpec>,
}

/// Typed argument for execution.
pub enum Arg<'a> {
    /// Borrowed 32-bit float buffer.
    F32(&'a [f32]),
    /// Borrowed 32-bit int buffer.
    I32(&'a [i32]),
}

pub use backend::{ArtifactRuntime, Executable};

#[cfg(feature = "pjrt")]
mod backend {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    use anyhow::{bail, Context, Result};

    use super::{parse_manifest, Arg, GraphSpec};

    /// A compiled artifact ready to execute.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        pub spec: GraphSpec,
        pub name: String,
    }

    // SAFETY: execution goes through the TFRT CPU PJRT client, which is
    // internally thread-safe; the non-atomic Rc inside the xla wrapper is
    // only touched when an Executable is dropped, and Executables are
    // always held behind Arc with the owning ArtifactRuntime kept alive
    // for the process lifetime (see service::). The wrapper types merely
    // lack derived markers.
    unsafe impl Send for Executable {}
    unsafe impl Sync for Executable {}

    impl Executable {
        /// Execute with flat buffers; returns one flat f32 vec per output.
        ///
        /// All current artifacts produce f32 outputs; extend on demand.
        pub fn run(&self, args: &[Arg<'_>]) -> Result<Vec<Vec<f32>>> {
            let spec = &self.spec;
            if args.len() != spec.inputs.len() {
                bail!(
                    "{}: expected {} inputs, got {}",
                    self.name,
                    spec.inputs.len(),
                    args.len()
                );
            }
            let mut literals = Vec::with_capacity(args.len());
            for (i, (arg, slot)) in args.iter().zip(&spec.inputs).enumerate() {
                let lit = match (arg, slot.dtype.as_str()) {
                    (Arg::F32(buf), "float32") => {
                        if buf.len() != slot.elements() {
                            bail!(
                                "{} input {i}: expected {} f32 elements, got {}",
                                self.name,
                                slot.elements(),
                                buf.len()
                            );
                        }
                        let dims: Vec<i64> = slot.dims.iter().map(|&d| d as i64).collect();
                        xla::Literal::vec1(buf).reshape(&dims)?
                    }
                    (Arg::I32(buf), "int32") => {
                        if buf.len() != slot.elements() {
                            bail!(
                                "{} input {i}: expected {} i32 elements, got {}",
                                self.name,
                                slot.elements(),
                                buf.len()
                            );
                        }
                        let dims: Vec<i64> = slot.dims.iter().map(|&d| d as i64).collect();
                        xla::Literal::vec1(buf).reshape(&dims)?
                    }
                    (_, want) => {
                        bail!("{} input {i}: dtype mismatch (manifest: {want})", self.name)
                    }
                };
                literals.push(lit);
            }
            let result = self.exe.execute::<xla::Literal>(&literals)?;
            // jax lowered with return_tuple=True: single tuple output
            let tuple = result[0][0]
                .to_literal_sync()?
                .to_tuple()
                .context("expected tuple output")?;
            if tuple.len() != spec.outputs.len() {
                bail!(
                    "{}: manifest promises {} outputs, artifact returned {}",
                    self.name,
                    spec.outputs.len(),
                    tuple.len()
                );
            }
            let mut out = Vec::with_capacity(tuple.len());
            for (lit, slot) in tuple.iter().zip(&spec.outputs) {
                let v: Vec<f32> = lit.to_vec()?;
                if v.len() != slot.elements() {
                    bail!(
                        "{}: output size {} != manifest {}",
                        self.name,
                        v.len(),
                        slot.elements()
                    );
                }
                out.push(v);
            }
            Ok(out)
        }
    }

    /// Manifest + lazily compiled executables over one PJRT CPU client.
    pub struct ArtifactRuntime {
        dir: PathBuf,
        client: xla::PjRtClient,
        specs: HashMap<String, GraphSpec>,
        compiled: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
    }

    // SAFETY: the PJRT CPU client and loaded executables are internally
    // thread-safe (TfrtCpuClient); the raw pointers in the xla wrapper
    // types lack auto-derived markers only.
    unsafe impl Send for ArtifactRuntime {}
    unsafe impl Sync for ArtifactRuntime {}

    impl ArtifactRuntime {
        /// Open the artifact directory (must contain manifest.txt).
        pub fn open(dir: &Path) -> Result<Self> {
            let manifest = dir.join("manifest.txt");
            let text = std::fs::read_to_string(&manifest)
                .with_context(|| format!("reading {}", manifest.display()))?;
            let specs = parse_manifest(&text)?;
            let client = xla::PjRtClient::cpu()?;
            Ok(Self {
                dir: dir.to_path_buf(),
                client,
                specs,
                compiled: Mutex::new(HashMap::new()),
            })
        }

        /// Default location: $COBI_ES_ARTIFACTS or ./artifacts.
        pub fn open_default() -> Result<Self> {
            let dir =
                std::env::var("COBI_ES_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
            Self::open(Path::new(&dir))
        }

        /// Names of compiled graphs in the manifest.
        pub fn graph_names(&self) -> Vec<String> {
            let mut v: Vec<String> = self.specs.keys().cloned().collect();
            v.sort();
            v
        }

        /// Spec for graph `name`, if present.
        pub fn spec(&self, name: &str) -> Option<&GraphSpec> {
            self.specs.get(name)
        }

        /// Get (compiling on first use) the executable for `name`.
        pub fn executable(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
            if let Some(e) = self.compiled.lock().unwrap().get(name) {
                return Ok(e.clone());
            }
            let spec = self
                .specs
                .get(name)
                .with_context(|| format!("unknown artifact '{name}'"))?
                .clone();
            let path = self.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            let executable = std::sync::Arc::new(Executable {
                exe,
                spec,
                name: name.to_string(),
            });
            self.compiled
                .lock()
                .unwrap()
                .insert(name.to_string(), executable.clone());
            Ok(executable)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    use std::path::Path;

    use anyhow::{bail, Result};

    use super::{Arg, GraphSpec};

    const UNAVAILABLE: &str = "PJRT support not compiled in: rebuild with \
         `--features pjrt` (and vendor the `xla` bindings crate); the \
         native backends cover everything else";

    /// Stub standing in for a compiled artifact; never constructible
    /// because the stub [`ArtifactRuntime::open`] always fails.
    pub struct Executable {
        pub spec: GraphSpec,
        pub name: String,
    }

    impl Executable {
        /// Stub execution: always errors (`pjrt` feature disabled).
        pub fn run(&self, _args: &[Arg<'_>]) -> Result<Vec<Vec<f32>>> {
            bail!("{}: {UNAVAILABLE}", self.name)
        }
    }

    /// Stub registry: opening always fails with a descriptive error so
    /// callers fall back to the native paths.
    pub struct ArtifactRuntime(());

    impl ArtifactRuntime {
        /// Open an artifact directory (manifest + graphs).
        pub fn open(dir: &Path) -> Result<Self> {
            bail!("cannot open artifacts at {}: {UNAVAILABLE}", dir.display())
        }

        /// Open `COBI_ES_ARTIFACTS` or `./artifacts`.
        pub fn open_default() -> Result<Self> {
            let dir =
                std::env::var("COBI_ES_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
            Self::open(Path::new(&dir))
        }

        /// Stub: no graphs without the `pjrt` feature.
        pub fn graph_names(&self) -> Vec<String> {
            Vec::new()
        }

        /// Stub: always `None`.
        pub fn spec(&self, _name: &str) -> Option<&GraphSpec> {
            None
        }

        /// Stub: errors descriptively.
        pub fn executable(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
            bail!("artifact '{name}': {UNAVAILABLE}")
        }
    }
}

#[cfg_attr(not(feature = "pjrt"), allow(dead_code))] // stub backend parses nothing
fn parse_manifest(text: &str) -> Result<HashMap<String, GraphSpec>> {
    let mut specs: HashMap<String, GraphSpec> = HashMap::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = line.split_whitespace().collect();
        if parts.len() != 6 {
            bail!("manifest line {}: expected 6 fields: '{line}'", lineno + 1);
        }
        let (name, file, kind, idx, dtype, dims) =
            (parts[0], parts[1], parts[2], parts[3], parts[4], parts[5]);
        let idx: usize = idx.parse().context("bad slot index")?;
        let dims: Vec<usize> = if dims == "scalar" {
            vec![]
        } else {
            dims.split('x')
                .map(|d| d.parse().context("bad dim"))
                .collect::<Result<_>>()?
        };
        let entry = specs.entry(name.to_string()).or_default();
        entry.file = file.to_string();
        let slot = SlotSpec {
            dtype: dtype.to_string(),
            dims,
        };
        let list = match kind {
            "in" => &mut entry.inputs,
            "out" => &mut entry.outputs,
            other => bail!("manifest line {}: bad kind '{other}'", lineno + 1),
        };
        if list.len() != idx {
            bail!(
                "manifest line {}: out-of-order slot {idx} (have {})",
                lineno + 1,
                list.len()
            );
        }
        list.push(slot);
    }
    Ok(specs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parser_happy_path() {
        let text = "\
# comment
energy energy.hlo.txt in 0 float32 64x64
energy energy.hlo.txt in 1 float32 64
energy energy.hlo.txt in 2 float32 32x64
energy energy.hlo.txt out 0 float32 32
";
        let specs = parse_manifest(text).unwrap();
        let e = &specs["energy"];
        assert_eq!(e.file, "energy.hlo.txt");
        assert_eq!(e.inputs.len(), 3);
        assert_eq!(e.inputs[0].dims, vec![64, 64]);
        assert_eq!(e.inputs[0].elements(), 4096);
        assert_eq!(e.outputs[0].dims, vec![32]);
    }

    #[test]
    fn manifest_rejects_malformed_lines() {
        assert!(parse_manifest("too few fields").is_err());
        assert!(parse_manifest("g f.hlo in 0 float32 8x8x").is_err());
        assert!(parse_manifest("g f.hlo sideways 0 float32 8").is_err());
        // out-of-order slots
        assert!(parse_manifest("g f.hlo in 1 float32 8").is_err());
    }

    #[test]
    fn scalar_dims_parse() {
        let specs = parse_manifest("g f.hlo in 0 float32 scalar").unwrap();
        assert_eq!(specs["g"].inputs[0].dims, Vec::<usize>::new());
        assert_eq!(specs["g"].inputs[0].elements(), 1);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_fails_loudly() {
        let err = ArtifactRuntime::open(std::path::Path::new("artifacts")).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
