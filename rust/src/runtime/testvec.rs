//! Test-vector loader for the rust<->jax numerical cross-check.
//!
//! Format written by python/compile/aot.py:write_testvec (little-endian):
//!   u32 n_arrays, then per array:
//!   u32 kind (0=input, 1=output), u32 dtype (0=f32, 1=i32), u32 rank,
//!   u32 dims[rank], payload (4 bytes/element).

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

/// One recorded tensor (input or output of a compiled graph).
#[derive(Debug, Clone)]
pub enum TestArray {
    /// 32-bit float tensor.
    F32 {
        /// Static shape.
        dims: Vec<usize>,
        /// Row-major elements.
        data: Vec<f32>,
    },
    /// 32-bit int tensor.
    I32 {
        /// Static shape.
        dims: Vec<usize>,
        /// Row-major elements.
        data: Vec<i32>,
    },
}

impl TestArray {
    /// Tensor dims.
    pub fn dims(&self) -> &[usize] {
        match self {
            TestArray::F32 { dims, .. } | TestArray::I32 { dims, .. } => dims,
        }
    }

    /// Float data, if this is an F32 tensor.
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            TestArray::F32 { data, .. } => Some(data),
            _ => None,
        }
    }

    /// Int data, if this is an I32 tensor.
    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            TestArray::I32 { data, .. } => Some(data),
            _ => None,
        }
    }
}

/// A compile-time-recorded (inputs, outputs) pair for numeric replay.
#[derive(Debug, Clone)]
pub struct TestVector {
    /// Graph inputs, in call order.
    pub inputs: Vec<TestArray>,
    /// Expected outputs.
    pub outputs: Vec<TestArray>,
}

struct Cursor<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    fn u32(&mut self) -> Result<u32> {
        ensure!(self.off + 4 <= self.buf.len(), "truncated test vector");
        let v = u32::from_le_bytes(self.buf[self.off..self.off + 4].try_into().unwrap());
        self.off += 4;
        Ok(v)
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.off + n <= self.buf.len(), "truncated payload");
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }
}

/// Load a binary test vector from `path`.
pub fn load(path: &Path) -> Result<TestVector> {
    let raw = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let mut cur = Cursor { buf: &raw, off: 0 };
    let n = cur.u32()? as usize;
    let mut inputs = Vec::new();
    let mut outputs = Vec::new();
    for _ in 0..n {
        let kind = cur.u32()?;
        let dtype = cur.u32()?;
        let rank = cur.u32()? as usize;
        ensure!(rank <= 8, "absurd rank {rank}");
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(cur.u32()? as usize);
        }
        let count: usize = dims.iter().product::<usize>().max(1);
        let payload = cur.bytes(count * 4)?;
        let arr = match dtype {
            0 => TestArray::F32 {
                dims,
                data: payload
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            },
            1 => TestArray::I32 {
                dims,
                data: payload
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            },
            other => bail!("bad dtype tag {other}"),
        };
        match kind {
            0 => inputs.push(arr),
            1 => outputs.push(arr),
            other => bail!("bad kind tag {other}"),
        }
    }
    ensure!(cur.off == raw.len(), "trailing bytes in test vector");
    Ok(TestVector { inputs, outputs })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn encode(arrays: &[(u32, u32, Vec<u32>, Vec<u8>)]) -> Vec<u8> {
        let mut out = (arrays.len() as u32).to_le_bytes().to_vec();
        for (kind, dt, dims, payload) in arrays {
            out.extend(kind.to_le_bytes());
            out.extend(dt.to_le_bytes());
            out.extend((dims.len() as u32).to_le_bytes());
            for d in dims {
                out.extend(d.to_le_bytes());
            }
            out.extend(payload);
        }
        out
    }

    #[test]
    fn round_trip() {
        let f = [1.5f32, -2.0];
        let payload: Vec<u8> = f.iter().flat_map(|v| v.to_le_bytes()).collect();
        let raw = encode(&[(0, 0, vec![2], payload)]);
        let dir = std::env::temp_dir().join("cobi_es_testvec_rt");
        std::fs::write(&dir, &raw).unwrap();
        let tv = load(&dir).unwrap();
        assert_eq!(tv.inputs.len(), 1);
        assert_eq!(tv.inputs[0].as_f32().unwrap(), &f);
        std::fs::remove_file(&dir).ok();
    }

    #[test]
    fn rejects_truncation_and_trailing() {
        let f = [1.0f32];
        let payload: Vec<u8> = f.iter().flat_map(|v| v.to_le_bytes()).collect();
        let mut raw = encode(&[(1, 0, vec![1], payload)]);
        raw.pop();
        let p = std::env::temp_dir().join("cobi_es_testvec_trunc");
        std::fs::write(&p, &raw).unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
