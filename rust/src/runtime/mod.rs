//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the L3 hot path. Python never runs here — the artifacts under
//! `artifacts/` are the only hand-off from the build-time JAX layer.
//!
//! The interchange format is HLO TEXT (not serialized protos): jax >= 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod artifacts;
pub mod encoder;
pub mod testvec;

pub use artifacts::{ArtifactRuntime, Executable};
pub use encoder::EncoderPipeline;

/// Quick PJRT availability probe (used by `cobi-es doctor` and tests).
#[cfg(feature = "pjrt")]
pub fn smoke() -> anyhow::Result<String> {
    let client = xla::PjRtClient::cpu()?;
    Ok(client.platform_name())
}

/// Stub probe: the default (offline) build carries no PJRT backend.
#[cfg(not(feature = "pjrt"))]
pub fn smoke() -> anyhow::Result<String> {
    anyhow::bail!("PJRT support not compiled in (rebuild with --features pjrt)")
}
