//! Artifact-backed embedder: tokenizer -> encoder.hlo -> cosine.hlo.
//!
//! This is the L2 embedding path executed from Rust: hashed tokens go
//! through the AOT transformer encoder, then the Pallas cosine artifact
//! produces (mu, beta). Implements `embed::Embedder`, so the pipeline can
//! swap it for the native hash embedder transparently.

use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::embed::{Embedder, Scores};
use crate::text::{Tokenizer, MAX_SENTENCES, MAX_TOKENS};

use super::artifacts::{Arg, ArtifactRuntime, Executable};

/// The AOT embedding path: encoder + cosine artifacts through PJRT.
pub struct EncoderPipeline {
    encoder: Arc<Executable>,
    cosine: Arc<Executable>,
    tokenizer: Tokenizer,
    embed_dim: usize,
}

impl EncoderPipeline {
    /// Build from the runtime's `encoder` and `cosine` graphs.
    pub fn new(rt: &ArtifactRuntime) -> Result<Self> {
        let encoder = rt.executable("encoder")?;
        let cosine = rt.executable("cosine")?;
        let embed_dim = encoder.spec.outputs[0].dims[1];
        ensure!(
            encoder.spec.inputs[0].dims == vec![MAX_SENTENCES, MAX_TOKENS],
            "encoder artifact shape {:?} does not match text constants",
            encoder.spec.inputs[0].dims
        );
        Ok(Self {
            encoder,
            cosine,
            tokenizer: Tokenizer::new(),
            embed_dim,
        })
    }

    /// Raw embeddings for up to MAX_SENTENCES sentences (padded rows
    /// dropped from the result).
    pub fn embed(&self, sentences: &[String]) -> Result<Vec<f32>> {
        let n = sentences.len();
        ensure!(n > 0, "empty document");
        ensure!(
            n <= MAX_SENTENCES,
            "document has {n} sentences; encoder batch is {MAX_SENTENCES} \
             (decompose first)"
        );
        let tokens = self.tokenizer.encode_batch(sentences, MAX_SENTENCES);
        let outs = self.encoder.run(&[Arg::I32(&tokens)])?;
        let full = &outs[0]; // MAX_SENTENCES x embed_dim
        Ok(full[..n * self.embed_dim].to_vec())
    }

    /// Full scores via the cosine artifact (padding masked inside).
    pub fn scores_via_artifact(&self, sentences: &[String]) -> Result<Scores> {
        let n = sentences.len();
        ensure!(n > 0 && n <= MAX_SENTENCES);
        let tokens = self.tokenizer.encode_batch(sentences, MAX_SENTENCES);
        let emb = self.encoder.run(&[Arg::I32(&tokens)])?.remove(0);
        let mut mask = vec![0.0f32; MAX_SENTENCES];
        for m in mask.iter_mut().take(n) {
            *m = 1.0;
        }
        let outs = self.cosine.run(&[Arg::F32(&emb), Arg::F32(&mask)])?;
        let (mu_full, beta_full) = (&outs[0], &outs[1]);
        // crop to n x n, zero the diagonal (artifact returns cos(e_i,e_i)=1)
        let mut mu = mu_full[..n].to_vec();
        let mut beta = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    beta[i * n + j] = beta_full[i * MAX_SENTENCES + j];
                }
            }
        }
        // guard: degenerate all-pad rows could make mu NaN; clamp instead
        for m in mu.iter_mut() {
            if !m.is_finite() {
                *m = 0.0;
            }
        }
        Ok(Scores { mu, beta })
    }
}

impl Embedder for EncoderPipeline {
    fn name(&self) -> &'static str {
        "aot-encoder"
    }

    fn scores(&mut self, sentences: &[String]) -> Result<Scores> {
        self.scores_via_artifact(sentences)
    }
}
