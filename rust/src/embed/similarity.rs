//! Relevance / redundancy scores from embeddings (paper Eqs. 1–2).
//!
//! Mirrors kernels/ref.py: mu_i = cos(e_i, mean(e_doc)), beta_ij =
//! cos(e_i, e_j). Shared by the native hash embedder and the PJRT encoder
//! path (which computes the same quantities inside the cosine artifact).

/// Relevance + redundancy for one document.
#[derive(Debug, Clone)]
pub struct Scores {
    /// mu_i, length n.
    pub mu: Vec<f32>,
    /// beta_ij, row-major n*n, symmetric, ZERO diagonal (self-similarity
    /// excluded: Eq. 3 sums run over i != j).
    pub beta: Vec<f32>,
}

impl Scores {
    /// Number of sentences scored.
    pub fn n(&self) -> usize {
        self.mu.len()
    }

    /// Restrict to a subset of sentence indices (decomposition windows).
    pub fn subset(&self, idx: &[usize]) -> Scores {
        let n = self.n();
        let m = idx.len();
        let mut mu = Vec::with_capacity(m);
        let mut beta = vec![0.0f32; m * m];
        for (a, &i) in idx.iter().enumerate() {
            assert!(i < n, "index {i} out of bounds {n}");
            mu.push(self.mu[i]);
            for (b, &j) in idx.iter().enumerate() {
                if a != b {
                    beta[a * m + b] = self.beta[i * n + j];
                }
            }
        }
        Scores { mu, beta }
    }
}

/// Dot product in the exact summation order every score in this module
/// uses. `pub(crate)` so the incremental streaming scorer
/// (`sched::stream`) reproduces batch scores bit for bit.
#[inline]
pub(crate) fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Euclidean norm (see [`dot`] for why this is `pub(crate)`).
#[inline]
pub(crate) fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Compute Scores from row-major embeddings (n x d).
pub fn scores_from_embeddings(emb: &[f32], n: usize, d: usize) -> Scores {
    assert_eq!(emb.len(), n * d);
    // unit rows
    let mut unit = vec![0.0f32; n * d];
    for i in 0..n {
        let row = &emb[i * d..(i + 1) * d];
        let nn = norm(row).max(1e-12);
        for k in 0..d {
            unit[i * d + k] = row[k] / nn;
        }
    }
    // document mean (over raw embeddings, like ref.relevance_ref)
    let mut doc = vec![0.0f32; d];
    for i in 0..n {
        for k in 0..d {
            doc[k] += emb[i * d + k];
        }
    }
    let dn = norm(&doc).max(1e-12);
    for v in doc.iter_mut() {
        *v /= dn;
    }
    let mu: Vec<f32> = (0..n)
        .map(|i| dot(&unit[i * d..(i + 1) * d], &doc))
        .collect();
    let mut beta = vec![0.0f32; n * n];
    for i in 0..n {
        for j in (i + 1)..n {
            let b = dot(&unit[i * d..(i + 1) * d], &unit[j * d..(j + 1) * d]);
            beta[i * n + j] = b;
            beta[j * n + i] = b;
        }
    }
    Scores { mu, beta }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_rows_give_unit_scores() {
        let emb = vec![1.0, 2.0, 2.0, 1.0, 2.0, 2.0];
        let s = scores_from_embeddings(&emb, 2, 3);
        assert!((s.mu[0] - 1.0).abs() < 1e-6);
        assert!((s.beta[1] - 1.0).abs() < 1e-6);
        assert_eq!(s.beta[0], 0.0, "diagonal must stay zero");
    }

    #[test]
    fn orthogonal_rows_give_zero_beta() {
        let emb = vec![1.0, 0.0, 0.0, 1.0];
        let s = scores_from_embeddings(&emb, 2, 2);
        assert!(s.beta[1].abs() < 1e-6);
    }

    #[test]
    fn scores_bounded_by_one() {
        let emb: Vec<f32> = (0..5 * 8).map(|i| ((i * 37 % 11) as f32) - 5.0).collect();
        let s = scores_from_embeddings(&emb, 5, 8);
        for &m in &s.mu {
            assert!(m.abs() <= 1.0 + 1e-5);
        }
        for &b in &s.beta {
            assert!(b.abs() <= 1.0 + 1e-5);
        }
    }

    #[test]
    fn subset_preserves_pairs() {
        let emb: Vec<f32> = (0..6 * 4).map(|i| (i as f32 * 0.7).sin()).collect();
        let s = scores_from_embeddings(&emb, 6, 4);
        let sub = s.subset(&[1, 3, 5]);
        assert_eq!(sub.n(), 3);
        assert_eq!(sub.mu[0], s.mu[1]);
        assert_eq!(sub.beta[0 * 3 + 1], s.beta[1 * 6 + 3]);
        assert_eq!(sub.beta[1 * 3 + 2], s.beta[3 * 6 + 5]);
        assert_eq!(sub.beta[0], 0.0);
    }

    #[test]
    fn subset_on_arbitrary_noncontiguous_index_sets() {
        // decomposition windows are usually contiguous ranges, but subset
        // must be correct for ANY index set: gaps, reversed order,
        // repeated indices, singletons, and the empty set
        let emb: Vec<f32> = (0..7 * 5).map(|i| ((i * 13 % 17) as f32 - 8.0) * 0.3).collect();
        let s = scores_from_embeddings(&emb, 7, 5);

        // reversed, gapped order: positions map by POSITION, not by value
        let idx = [6, 0, 4];
        let sub = s.subset(&idx);
        assert_eq!(sub.n(), 3);
        for (a, &i) in idx.iter().enumerate() {
            assert_eq!(sub.mu[a], s.mu[i], "mu position {a}");
            for (b, &j) in idx.iter().enumerate() {
                let expect = if a == b { 0.0 } else { s.beta[i * 7 + j] };
                assert_eq!(sub.beta[a * 3 + b], expect, "beta ({a},{b})");
            }
        }
        // symmetry survives because the source is symmetric
        assert_eq!(sub.beta[2], sub.beta[2 * 3]);

        // a repeated index yields a ZERO diagonal block even off-diagonal
        // (a != b but i == j picks the source diagonal, which is zero);
        // the duplicated row's cross terms still match the source
        let dup = s.subset(&[2, 2, 5]);
        assert_eq!(dup.beta[1], s.beta[2 * 7 + 2]);
        assert_eq!(dup.beta[1], 0.0);
        assert_eq!(dup.beta[2], s.beta[2 * 7 + 5]);
        assert_eq!(dup.beta[3 + 2], s.beta[2 * 7 + 5]);

        // singleton and empty sets
        let one = s.subset(&[3]);
        assert_eq!(one.n(), 1);
        assert_eq!(one.mu[0], s.mu[3]);
        assert_eq!(one.beta, vec![0.0]);
        let none = s.subset(&[]);
        assert_eq!(none.n(), 0);
        assert!(none.beta.is_empty());
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn subset_rejects_out_of_range_indices() {
        let emb: Vec<f32> = (0..4 * 3).map(|i| i as f32).collect();
        let s = scores_from_embeddings(&emb, 4, 3);
        s.subset(&[1, 4]);
    }
}
