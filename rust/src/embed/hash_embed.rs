//! Native hashed-random-projection embedder.
//!
//! A fast, dependency-free stand-in for the AOT encoder artifact: each
//! hashed token id deterministically seeds a gaussian d-vector; a sentence
//! embedding is the mean of its token vectors plus a small positive common
//! component (mimicking the anisotropy of SBERT news embeddings, which
//! keeps all-pairs cosine similarity positive — the property the dense
//! Ising formulation relies on).
//!
//! Used by default in tests/benches (no PJRT startup cost); the pipeline
//! swaps in `runtime::EncoderPipeline` for artifact-faithful embeddings.

use crate::text::{Tokenizer, MAX_TOKENS};
use crate::util::rng::SplitMix64;

use super::similarity::{scores_from_embeddings, Scores};
use super::Embedder;

/// Embedding dimensionality (matches the artifact's D = 64).
pub const EMBED_DIM: usize = 64;

/// Shared positive component weight (anisotropy strength).
const COMMON_WEIGHT: f32 = 0.6;

/// Hashed-random-projection embedder (module docs).
pub struct HashEmbedder {
    /// Common direction added to every sentence embedding.
    common: Vec<f32>,
    tokenizer: Tokenizer,
}

impl Default for HashEmbedder {
    fn default() -> Self {
        Self::new()
    }
}

impl HashEmbedder {
    /// Embedder with the fixed deterministic common component.
    pub fn new() -> Self {
        let mut rng = SplitMix64::new(0xC0FF_EE00);
        let common: Vec<f32> = (0..EMBED_DIM)
            .map(|_| gaussian_from_bits(rng.next_u64()))
            .collect();
        Self {
            common,
            tokenizer: Tokenizer::new(),
        }
    }

    /// Deterministic token vector: SplitMix64 stream keyed by token id.
    fn token_vector(&self, token_id: i32) -> [f32; EMBED_DIM] {
        let mut rng = SplitMix64::new(token_id as u64 ^ 0x7E11_BEEF);
        let mut v = [0.0f32; EMBED_DIM];
        for x in v.iter_mut() {
            *x = gaussian_from_bits(rng.next_u64());
        }
        v
    }

    /// Embed one sentence: mean token vector + common component.
    pub fn embed_sentence(&self, sentence: &str) -> Vec<f32> {
        let row = self.tokenizer.encode_sentence(sentence);
        let mut acc = vec![0.0f32; EMBED_DIM];
        let mut count = 0usize;
        for &tok in row.iter().take(MAX_TOKENS) {
            if tok == 0 {
                break;
            }
            let v = self.token_vector(tok);
            for (a, x) in acc.iter_mut().zip(v.iter()) {
                *a += x;
            }
            count += 1;
        }
        if count > 0 {
            for a in acc.iter_mut() {
                *a /= count as f32;
            }
        }
        for (a, c) in acc.iter_mut().zip(self.common.iter()) {
            *a += COMMON_WEIGHT * c / (EMBED_DIM as f32).sqrt();
        }
        acc
    }
}

/// Crude-but-deterministic standard normal from 64 random bits
/// (sum of 8 uniform bytes, CLT; adequate for embedding geometry).
fn gaussian_from_bits(bits: u64) -> f32 {
    let mut s = 0.0f32;
    for k in 0..8 {
        s += ((bits >> (8 * k)) & 0xFF) as f32 / 255.0;
    }
    // mean 4.0, var 8/12 -> standardize
    (s - 4.0) / (8.0f32 / 12.0).sqrt()
}

impl Embedder for HashEmbedder {
    fn name(&self) -> &'static str {
        "hash"
    }

    fn scores(&mut self, sentences: &[String]) -> anyhow::Result<Scores> {
        let n = sentences.len();
        anyhow::ensure!(n > 0, "empty document");
        let mut emb = vec![0.0f32; n * EMBED_DIM];
        for (i, s) in sentences.iter().enumerate() {
            let e = self.embed_sentence(s);
            emb[i * EMBED_DIM..(i + 1) * EMBED_DIM].copy_from_slice(&e);
        }
        Ok(scores_from_embeddings(&emb, n, EMBED_DIM))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Generator;

    fn doc_scores(seed: u64, n: usize) -> Scores {
        let mut g = Generator::with_seed(seed);
        let d = g.document("t", n);
        HashEmbedder::new().scores(&d.sentences).unwrap()
    }

    #[test]
    fn deterministic() {
        let a = doc_scores(1, 12);
        let b = doc_scores(1, 12);
        assert_eq!(a.mu, b.mu);
        assert_eq!(a.beta, b.beta);
    }

    #[test]
    fn sbert_like_geometry() {
        // dense positive similarity: the property the dense Ising
        // formulation depends on (paper §III-A: "beta_ij != 0 forall i,j")
        let s = doc_scores(2, 20);
        let n = s.n();
        let mut pos = 0usize;
        let mut total = 0usize;
        for i in 0..n {
            assert!(s.mu[i] > 0.0, "mu[{i}] = {}", s.mu[i]);
            for j in (i + 1)..n {
                total += 1;
                pos += (s.beta[i * n + j] > 0.0) as usize;
                assert!(
                    s.beta[i * n + j].abs() > 1e-6,
                    "zero beta at ({i},{j}) — dense coupling violated"
                );
            }
        }
        assert!(pos as f64 / total as f64 > 0.9, "{pos}/{total} positive");
    }

    #[test]
    fn same_topic_pairs_more_redundant() {
        use crate::corpus::GeneratorConfig;
        // single-topic doc vs mixed: mean beta should drop for mixed
        let mut g1 = Generator::new(
            3,
            GeneratorConfig {
                topics_per_doc: 1,
                coherence: 1.0,
                key_facts: 3,
            },
        );
        let mut g8 = Generator::new(
            4,
            GeneratorConfig {
                topics_per_doc: 6,
                coherence: 0.0,
                key_facts: 3,
            },
        );
        let mut e = HashEmbedder::new();
        let s1 = e.scores(&g1.document("a", 16).sentences).unwrap();
        let s8 = e.scores(&g8.document("b", 16).sentences).unwrap();
        let mean_off = |s: &Scores| {
            let n = s.n();
            let mut acc = 0.0f64;
            let mut c = 0usize;
            for i in 0..n {
                for j in (i + 1)..n {
                    acc += s.beta[i * n + j] as f64;
                    c += 1;
                }
            }
            acc / c as f64
        };
        assert!(
            mean_off(&s1) > mean_off(&s8) + 0.03,
            "single-topic {:.3} vs mixed {:.3}",
            mean_off(&s1),
            mean_off(&s8)
        );
    }

    #[test]
    fn empty_document_is_error() {
        assert!(HashEmbedder::new().scores(&[]).is_err());
    }
}
