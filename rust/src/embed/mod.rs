//! Embedding layer: sentences -> (mu, beta) scores (paper Eqs. 1–2).
//!
//! Two implementations:
//!   * [`HashEmbedder`] — native hashed random projection, fast and
//!     dependency-free (tests, benches, fallback);
//!   * `runtime::EncoderPipeline` — the AOT path: the JAX transformer
//!     encoder + Pallas cosine kernel executed through PJRT.
//!
//! Both satisfy [`Embedder`], so the pipeline is backend-agnostic.

pub mod hash_embed;
pub mod similarity;

pub use hash_embed::HashEmbedder;
pub use similarity::{scores_from_embeddings, Scores};

/// Sentences -> relevance/redundancy scores.
pub trait Embedder {
    /// Stable embedder name for reports.
    fn name(&self) -> &'static str;
    /// Relevance/redundancy scores for `sentences` (paper Eqs. 1-2).
    fn scores(&mut self, sentences: &[String]) -> anyhow::Result<Scores>;
}
