//! Deterministic PRNGs for the coordinator.
//!
//! The vendored crate set has no `rand`, so the repo carries its own
//! generators: [`SplitMix64`] for seeding / cheap streams and [`Pcg32`]
//! (PCG-XSH-RR 64/32) as the workhorse. Both are tiny, fast, and —
//! crucially for the experiment harness — fully reproducible from a `u64`
//! seed, so every figure in EXPERIMENTS.md can be regenerated bit-for-bit.

/// SplitMix64: the canonical seeding generator (Steele et al., OOPSLA'14).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Generator starting at `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32 (O'Neill 2014): small state, good statistical quality,
/// supports independent streams via the odd increment.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6_364_136_223_846_793_005;

impl Pcg32 {
    /// Seed a generator; `stream` selects one of 2^63 independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: seed from a single value (stream 0xDA3E39CB94B95BDB).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xDA3E_39CB_94B9_5BDB)
    }

    /// Next 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 random bits (two 32-bit draws, high word first).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        // 24 mantissa-ish bits; exact in f32.
        (self.next_u32() >> 8) as f32 * (1.0 / 16_777_216.0)
    }

    /// Uniform in [0, 1) with f64 resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / 9_007_199_254_740_992.0)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Unbiased uniform integer in [0, n) (Lemire rejection method).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64) * (n as u64);
            let lo = m as u32;
            if lo >= n || lo >= (n.wrapping_neg() % n) {
                return (m >> 32) as u32;
            }
        }
    }

    /// Standard normal via Box–Muller (single draw; batch callers use
    /// [`Pcg32::fill_normal`], which keeps the paired second value).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-12);
        let u2 = self.f32();
        let r = (-2.0 * u1.ln()).sqrt();
        r * (std::f32::consts::TAU * u2).cos()
    }

    /// Fill `out` with i.i.d. N(0, sigma^2) draws. Uses the full
    /// Box–Muller pair (sin and cos branches), halving the ln/sqrt cost
    /// versus per-sample `normal()` — this feeds the COBI device's
    /// per-solve noise tensor, a §Perf hot path.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        let mut i = 0;
        while i + 1 < out.len() {
            let u1 = self.f32().max(1e-12);
            let u2 = self.f32();
            let r = (-2.0 * u1.ln()).sqrt() * sigma;
            let (s, c) = (std::f32::consts::TAU * u2).sin_cos();
            out[i] = r * c;
            out[i + 1] = r * s;
            i += 2;
        }
        if i < out.len() {
            out[i] = self.normal() * sigma;
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u32) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference sequence for seed 1234567 (computed from the published
        // SplitMix64 algorithm).
        let mut r = SplitMix64::new(0);
        let a = r.next_u64();
        let b = r.next_u64();
        assert_ne!(a, b);
        let mut r2 = SplitMix64::new(0);
        assert_eq!(a, r2.next_u64());
    }

    #[test]
    fn pcg_deterministic_and_stream_independent() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 1);
        let mut c = Pcg32::new(42, 2);
        let xs: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let ys: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        let zs: Vec<u32> = (0..8).map(|_| c.next_u32()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut r = Pcg32::seeded(3);
        let mut counts = [0usize; 5];
        let draws = 50_000;
        for _ in 0..draws {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            let p = c as f64 / draws as f64;
            assert!((p - 0.2).abs() < 0.01, "p={p}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(11);
        let n = 100_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Pcg32::seeded(5);
        for _ in 0..100 {
            let s = r.sample_indices(20, 6);
            assert_eq!(s.len(), 6);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 6);
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    /// Crate-wide stream-id audit: every RNG stream that derives draws
    /// from request/solver seeds must be pairwise distinct, or two
    /// subsystems seeded with the same request seed would replay each
    /// other's sequences (e.g. a solver consuming the quantizer's
    /// rounding draws). Covers the named constants plus the inline
    /// stream literals of the seeded solvers and the `Pcg32::seeded`
    /// default. Deliberately OUT of scope: the pipeline's quantization
    /// call sites reuse `QUANT_STREAM`'s value by design (the scheduler
    /// must replay the inline pipeline's draws), and the synthetic
    /// corpus generator's stream lives in the document domain, never
    /// mixing with request seeds.
    ///
    /// `Pcg32::new` folds the stream into the increment as
    /// `(stream << 1) | 1`, so bit 63 is discarded — the audit compares
    /// the *effective* 63-bit increments, not the raw constants.
    #[test]
    fn rng_stream_ids_are_pairwise_distinct() {
        let streams: &[(&str, u64)] = &[
            ("client-seed (sched::pool)", crate::sched::pool::CLIENT_SEED_STREAM),
            ("quantize (sched)", crate::sched::QUANT_STREAM),
            ("bandit (portfolio)", crate::portfolio::BANDIT_STREAM),
            (
                "latency reservoir (service::metrics)",
                crate::service::metrics::RESERVOIR_STREAM,
            ),
            ("adapter-seed (resilience)", crate::resilience::ADAPTER_SEED_STREAM),
            ("fault (resilience::fault)", crate::resilience::fault::FAULT_STREAM),
            ("device noise (cobi::device)", crate::cobi::device::DEVICE_STREAM),
            (
                "retry-after jitter (service::overload)",
                crate::service::overload::RETRY_JITTER_STREAM,
            ),
            ("snowball spins", crate::solvers::snowball::SNOWBALL_STREAM),
            (
                "snowball schedule",
                crate::solvers::snowball::SNOWBALL_SCHEDULE_STREAM,
            ),
            ("tabu (inline, solvers::tabu)", 0x7AB0),
            ("sa (inline, solvers::sa)", 0x5A5A),
            ("oscillator (inline, solvers::oscillator)", 0x05C1),
            ("random (inline, solvers::random)", 0xBA5E),
            ("portfolio seeds (inline, portfolio)", 0x5EED0F),
            ("Pcg32::seeded default", 0xDA3E_39CB_94B9_5BDB),
        ];
        const EFFECTIVE: u64 = u64::MAX >> 1;
        for (i, (a_name, a)) in streams.iter().enumerate() {
            for (b_name, b) in &streams[i + 1..] {
                assert_ne!(
                    a & EFFECTIVE,
                    b & EFFECTIVE,
                    "stream collision: '{a_name}' and '{b_name}' share increment {:#x}",
                    a & EFFECTIVE
                );
            }
        }
    }
}
