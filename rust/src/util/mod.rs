//! Shared substrates: PRNG, statistics, benchmarking, property testing.
//!
//! The vendored crate set is deliberately tiny (DESIGN.md decision #5),
//! so the infrastructure other repos pull from crates.io lives here:
//! `rng` (seeded PCG/SplitMix streams — the root of the repo-wide
//! determinism story), `stats` (means/quantiles shared by experiments
//! and metrics), `bench` (the criterion-substitute harness behind every
//! `benches/` target, env-tunable via `COBI_BENCH_*`), and `proptest`
//! (a minimal seeded property-testing loop used by the unit tests).

pub mod bench;
pub mod proptest;
pub mod rng;
pub mod stats;
