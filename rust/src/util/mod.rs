//! Shared substrates: PRNG, statistics, benchmarking, property testing.

pub mod bench;
pub mod proptest;
pub mod rng;
pub mod stats;
