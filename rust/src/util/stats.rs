//! Small statistics toolkit: summaries used by every experiment driver.

/// Five-number summary + mean, matching the paper's boxplot conventions
/// (Fig 1/5/6: whiskers = min/max, box = quartiles, cross/line = mean).
#[derive(Debug, Clone, PartialEq)]
pub struct BoxStats {
    /// Smallest value.
    pub min: f64,
    /// Lower quartile.
    pub q25: f64,
    /// Median.
    pub median: f64,
    /// Upper quartile.
    pub q75: f64,
    /// Largest value.
    pub max: f64,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample count.
    pub n: usize,
}

impl BoxStats {
    /// Summarize `values` (panics on an empty slice or NaN).
    pub fn compute(values: &[f64]) -> Self {
        assert!(!values.is_empty(), "BoxStats of empty slice");
        let mut v = values.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in stats"));
        Self {
            min: v[0],
            q25: quantile_sorted(&v, 0.25),
            median: quantile_sorted(&v, 0.5),
            q75: quantile_sorted(&v, 0.75),
            max: v[v.len() - 1],
            mean: mean(&v),
            n: v.len(),
        }
    }

    /// Render as the compact row format used in experiment reports.
    pub fn row(&self) -> String {
        format!(
            "min={:.3} q25={:.3} med={:.3} q75={:.3} max={:.3} mean={:.3} (n={})",
            self.min, self.q25, self.median, self.q75, self.max, self.mean, self.n
        )
    }
}

/// Arithmetic mean (NaN for an empty slice).
pub fn mean(values: &[f64]) -> f64 {
    if values.is_empty() {
        return f64::NAN;
    }
    values.iter().sum::<f64>() / values.len() as f64
}

/// Sample variance (0 below two values).
pub fn variance(values: &[f64]) -> f64 {
    if values.len() < 2 {
        return 0.0;
    }
    let m = mean(values);
    values.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (values.len() - 1) as f64
}

/// Sample standard deviation.
pub fn stddev(values: &[f64]) -> f64 {
    variance(values).sqrt()
}

/// Median of an unsorted slice (copies; callers on hot paths sort once and
/// use `quantile_sorted` directly).
pub fn median(values: &[f64]) -> f64 {
    let mut v = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in median"));
    quantile_sorted(&v, 0.5)
}

/// Linear-interpolation quantile (type-7, numpy default) of a sorted slice.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Median of f32 values (convenience for Ising coefficient vectors).
/// Hot paths that already own a scratch slice use
/// [`median_f32_in_place`] instead — identical result, no f64 copy.
pub fn median_f32(values: &[f32]) -> f32 {
    let v: Vec<f64> = values.iter().map(|&x| x as f64).collect();
    median(&v) as f32
}

/// Median of an f32 scratch slice, sorted in place — bit-identical to
/// [`median_f32`] (same sort order for non-NaN data; the two middle
/// elements interpolate in f64 exactly as `quantile_sorted` does) without
/// allocating the intermediate f64 vector.
pub fn median_f32_in_place(values: &mut [f32]) -> f32 {
    assert!(!values.is_empty(), "median of empty slice");
    values.sort_by(|a, b| a.partial_cmp(b).expect("NaN in median"));
    if values.len() == 1 {
        return values[0];
    }
    let pos = 0.5 * (values.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        values[lo]
    } else {
        let w = pos - lo as f64;
        (values[lo] as f64 * (1.0 - w) + values[hi] as f64 * w) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_match_numpy_type7() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert!((quantile_sorted(&v, 0.5) - 2.5).abs() < 1e-12);
        assert!((quantile_sorted(&v, 0.25) - 1.75).abs() < 1e-12);
        assert!((quantile_sorted(&v, 0.75) - 3.25).abs() < 1e-12);
        assert!((quantile_sorted(&v, 0.0) - 1.0).abs() < 1e-12);
        assert!((quantile_sorted(&v, 1.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn box_stats_basic() {
        let b = BoxStats::compute(&[3.0, 1.0, 2.0, 5.0, 4.0]);
        assert_eq!(b.min, 1.0);
        assert_eq!(b.max, 5.0);
        assert_eq!(b.median, 3.0);
        assert_eq!(b.mean, 3.0);
        assert_eq!(b.n, 5);
    }

    #[test]
    fn median_even_odd() {
        assert_eq!(median(&[1.0, 3.0, 2.0]), 2.0);
        assert_eq!(median(&[1.0, 2.0, 3.0, 4.0]), 2.5);
    }

    #[test]
    fn median_f32_in_place_matches_median_f32_bitwise() {
        let mut state = 0x2545F4914F6CDD1Du64;
        for len in [1usize, 2, 3, 4, 7, 10, 31, 100] {
            let values: Vec<f32> = (0..len)
                .map(|_| {
                    state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                    ((state >> 40) as f32 / 1000.0) - 8.0
                })
                .collect();
            let reference = median_f32(&values);
            let mut scratch = values.clone();
            let in_place = median_f32_in_place(&mut scratch);
            assert_eq!(in_place.to_bits(), reference.to_bits(), "len {len}");
        }
    }

    #[test]
    fn variance_of_constants_is_zero() {
        assert_eq!(variance(&[2.0, 2.0, 2.0]), 0.0);
    }

    #[test]
    #[should_panic]
    fn empty_box_stats_panics() {
        BoxStats::compute(&[]);
    }
}
