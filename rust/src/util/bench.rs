//! Mini benchmark harness (criterion substitute — see Cargo.toml note).
//!
//! Provides the measurement loop the `benches/*.rs` targets (harness =
//! false) use: warm-up, adaptive iteration count, and a robust summary
//! (median + MAD) printed in a criterion-like format. Good enough for the
//! before/after deltas recorded in EXPERIMENTS.md §Perf; not a statistics
//! engine.

use std::time::{Duration, Instant};

/// One case's measurement summary.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Case name.
    pub name: String,
    /// Iterations measured.
    pub iters: u64,
    /// Median per-op time.
    pub median: Duration,
    /// Mean per-op time.
    pub mean: Duration,
    /// Fastest per-op time.
    pub min: Duration,
    /// Slowest per-op time.
    pub max: Duration,
}

impl BenchResult {
    /// Criterion-style one-line summary.
    pub fn report(&self) -> String {
        format!(
            "{:<44} time: [{} {} {}]  ({} iters)",
            self.name,
            fmt_dur(self.min),
            fmt_dur(self.median),
            fmt_dur(self.max),
            self.iters
        )
    }
}

/// Human-readable duration (ns / us / ms / s).
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Benchmark runner: call [`Bencher::bench`] per case; results accumulate
/// and print immediately.
pub struct Bencher {
    /// Target measurement time per case.
    pub measure_time: Duration,
    /// Warm-up time per case.
    pub warmup_time: Duration,
    /// Results in run order.
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            // Keep defaults modest: the suite covers many paper figures and
            // runs on a single-core CI box. Override via env if needed.
            measure_time: env_duration("COBI_BENCH_MEASURE_MS", 700),
            warmup_time: env_duration("COBI_BENCH_WARMUP_MS", 200),
            results: Vec::new(),
        }
    }
}

fn env_duration(var: &str, default_ms: u64) -> Duration {
    std::env::var(var)
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .map(Duration::from_millis)
        .unwrap_or(Duration::from_millis(default_ms))
}

impl Bencher {
    /// Runner with env-tunable default budgets.
    pub fn new() -> Self {
        Self::default()
    }

    /// Measure `f`, which should perform ONE logical operation per call.
    /// Returns the result and prints a summary line.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warm-up and initial rate estimate.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup_time {
            f();
            warm_iters += 1;
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters.max(1) as f64;

        // Sample in batches; record per-batch mean to reduce timer overhead.
        let batch = ((0.01 / per_iter.max(1e-9)).ceil() as u64).clamp(1, 1 << 20);
        let mut samples: Vec<f64> = Vec::new();
        let mut total_iters = 0u64;
        let run_start = Instant::now();
        while run_start.elapsed() < self.measure_time || samples.len() < 5 {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t.elapsed().as_secs_f64() / batch as f64);
            total_iters += batch;
            if samples.len() > 10_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = samples[samples.len() / 2];
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let res = BenchResult {
            name: name.to_string(),
            iters: total_iters,
            median: Duration::from_secs_f64(med),
            mean: Duration::from_secs_f64(mean),
            min: Duration::from_secs_f64(samples[0]),
            max: Duration::from_secs_f64(samples[samples.len() - 1]),
        };
        println!("{}", res.report());
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Time a single execution of `f` (for long end-to-end cases where an
    /// adaptive loop would blow the time budget); prints and records it.
    pub fn bench_once<F: FnOnce()>(&mut self, name: &str, f: F) -> &BenchResult {
        let t = Instant::now();
        f();
        let d = t.elapsed();
        let res = BenchResult {
            name: name.to_string(),
            iters: 1,
            median: d,
            mean: d,
            min: d,
            max: d,
        };
        println!("{}", res.report());
        self.results.push(res);
        self.results.last().unwrap()
    }
}

/// Prevent the optimizer from eliding a computed value (std::hint::black_box
/// wrapper kept behind one name so benches read uniformly).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher {
            measure_time: Duration::from_millis(30),
            warmup_time: Duration::from_millis(5),
            results: Vec::new(),
        };
        let mut acc = 0u64;
        b.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].iters > 0);
        assert!(b.results[0].median.as_nanos() < 1_000_000);
    }

    #[test]
    fn fmt_dur_scales() {
        assert!(fmt_dur(Duration::from_nanos(50)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(50)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(50)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }
}
