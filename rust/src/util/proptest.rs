//! Mini property-test driver (proptest substitute — see Cargo.toml note).
//!
//! Runs a property over `cases` randomized inputs drawn from a seeded
//! [`Pcg32`]; on failure it reports the case index and seed so the exact
//! input can be regenerated. Coordinator invariants (routing, batching,
//! formulation, quantization) use this via `check(..)`.
//!
//! Two refinements over the bare loop:
//!
//! * **Replay** — every failure message embeds a ready-to-paste
//!   [`replay`] / [`replay_sized`] call that re-runs exactly the failing
//!   case (same derived RNG), so a CI failure reproduces locally without
//!   re-running the whole sweep.
//! * **Shrinking** — [`check_sized`] ramps an explicit size parameter
//!   across cases and, on failure, re-runs the SAME case seed at every
//!   smaller size, reporting the minimal size that still fails. RNG-drawn
//!   inputs have no structure to shrink generically, so the size channel
//!   is the shrink axis: properties route their "how big" decisions
//!   (sentence counts, spin counts, selection widths) through it and get
//!   minimal counterexamples for free.

use super::rng::Pcg32;

/// Default number of cases per property (kept moderate: the repo has many
/// properties and CI is single-core).
pub const DEFAULT_CASES: u32 = 128;

/// Run `prop` over `cases` randomized cases. The property receives a fresh
/// deterministic RNG per case (seed derives from `seed` + case index) and
/// returns `Err(msg)` to signal failure.
pub fn check<F>(name: &str, seed: u64, cases: u32, mut prop: F)
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    for case in 0..cases {
        let mut rng = case_rng(seed, case);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (case_seed={seed:#x}; replay with \
                 proptest::replay(\"{name}\", {seed:#x}, {case}, prop)): {msg}"
            );
        }
    }
}

/// The deterministic per-case RNG `check`/`check_sized` hand to case
/// `case` of a `seed`-keyed property (the replay entry points rebuild
/// exactly this stream).
fn case_rng(seed: u64, case: u32) -> Pcg32 {
    let case_seed = seed
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(case as u64);
    Pcg32::new(case_seed, case as u64 + 1)
}

/// Re-run ONE case of a [`check`] property (the failure message names the
/// arguments). Panics with the property's message if it still fails,
/// passes silently if the property was since fixed.
pub fn replay<F>(name: &str, seed: u64, case: u32, mut prop: F)
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    let mut rng = case_rng(seed, case);
    if let Err(msg) = prop(&mut rng) {
        panic!("property '{name}' replay of case {case} (seed={seed:#x}) failed: {msg}");
    }
}

/// [`check`] with an explicit size channel and shrinking (see module
/// docs): case `k` of `cases` runs at `size = 1 + k * max_size / cases`
/// (a deterministic ramp from small to `max_size`), and a failure is
/// re-run at every smaller size — same case seed — to report the
/// minimal failing size alongside the original one.
pub fn check_sized<F>(name: &str, seed: u64, cases: u32, max_size: usize, mut prop: F)
where
    F: FnMut(&mut Pcg32, usize) -> Result<(), String>,
{
    assert!(max_size >= 1, "max_size must be at least 1");
    for case in 0..cases {
        let size = 1 + (case as usize * max_size) / cases.max(1) as usize;
        let size = size.min(max_size);
        let mut rng = case_rng(seed, case);
        if let Err(msg) = prop(&mut rng, size) {
            // shrink: walk every smaller size under the same case seed
            // and keep the smallest one that still fails
            let (mut min_size, mut min_msg) = (size, msg);
            for s in (1..size).rev() {
                let mut rng = case_rng(seed, case);
                if let Err(m) = prop(&mut rng, s) {
                    min_size = s;
                    min_msg = m;
                }
            }
            panic!(
                "property '{name}' failed at case {case}/{cases} size {size} \
                 (minimal failing size {min_size}; replay with \
                 proptest::replay_sized(\"{name}\", {seed:#x}, {case}, {min_size}, prop)): \
                 {min_msg}"
            );
        }
    }
}

/// Re-run ONE case of a [`check_sized`] property at an explicit size (the
/// failure message names the arguments, already shrunk to minimal).
pub fn replay_sized<F>(name: &str, seed: u64, case: u32, size: usize, mut prop: F)
where
    F: FnMut(&mut Pcg32, usize) -> Result<(), String>,
{
    let mut rng = case_rng(seed, case);
    if let Err(msg) = prop(&mut rng, size) {
        panic!(
            "property '{name}' replay of case {case} size {size} (seed={seed:#x}) failed: {msg}"
        );
    }
}

/// Shorthand with [`DEFAULT_CASES`].
pub fn check_default<F>(name: &str, seed: u64, prop: F)
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    check(name, seed, DEFAULT_CASES, prop)
}

/// Assert helper: turn a boolean + context into the property result type.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check("tautology", 1, 64, |rng| {
            let x = rng.f32();
            prop_assert!((0.0..1.0).contains(&x), "x out of range: {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics_with_context() {
        check("always-false", 2, 8, |_| Err("nope".into()));
    }

    #[test]
    fn replay_reproduces_the_exact_case_stream() {
        // draw one value per case via check, then replay a middle case
        // and get the identical draw
        let mut draws: Vec<u32> = Vec::new();
        check("collect-for-replay", 7, 8, |rng| {
            draws.push(rng.next_u32());
            Ok(())
        });
        let mut replayed = None;
        replay("collect-for-replay", 7, 5, |rng| {
            replayed = Some(rng.next_u32());
            Ok(())
        });
        assert_eq!(replayed, Some(draws[5]));
    }

    #[test]
    #[should_panic(expected = "replay of case 0")]
    fn replay_panics_on_a_still_failing_case() {
        replay("still-broken", 1, 0, |_| Err("still broken".into()));
    }

    #[test]
    fn sized_cases_ramp_up_to_max_size() {
        let mut sizes: Vec<usize> = Vec::new();
        check_sized("ramp", 4, 16, 40, |_, size| {
            sizes.push(size);
            Ok(())
        });
        assert_eq!(sizes.len(), 16);
        assert_eq!(sizes[0], 1, "the ramp starts minimal");
        assert!(sizes.windows(2).all(|w| w[0] <= w[1]), "ramp is monotone");
        assert!(*sizes.last().unwrap() <= 40);
        assert!(sizes.iter().all(|&s| (1..=40).contains(&s)));
    }

    #[test]
    #[should_panic(expected = "minimal failing size 7")]
    fn shrinking_reports_the_minimal_failing_size() {
        // fails for size >= 7: the first failing case runs at some larger
        // ramped size, and shrinking must walk it down to exactly 7
        check_sized("shrinks-to-seven", 5, 32, 64, |_, size| {
            if size >= 7 {
                Err(format!("too big: {size}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn replay_sized_reruns_one_size() {
        let mut seen = None;
        replay_sized("one-size", 9, 3, 17, |rng, size| {
            seen = Some((rng.next_u32(), size));
            Ok(())
        });
        let (draw, size) = seen.unwrap();
        assert_eq!(size, 17);
        // same case seed as check_sized case 3 of seed 9
        let mut expect = None;
        replay("one-size", 9, 3, |rng| {
            expect = Some(rng.next_u32());
            Ok(())
        });
        assert_eq!(Some(draw), expect);
    }

    #[test]
    fn cases_are_deterministic_across_runs() {
        let mut first: Vec<u32> = Vec::new();
        check("collect", 3, 16, |rng| {
            first.push(rng.next_u32());
            Ok(())
        });
        let mut second: Vec<u32> = Vec::new();
        check("collect", 3, 16, |rng| {
            second.push(rng.next_u32());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
