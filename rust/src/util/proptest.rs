//! Mini property-test driver (proptest substitute — see Cargo.toml note).
//!
//! Runs a property over `cases` randomized inputs drawn from a seeded
//! [`Pcg32`]; on failure it reports the case index and seed so the exact
//! input can be regenerated. Coordinator invariants (routing, batching,
//! formulation, quantization) use this via `check(..)`.

use super::rng::Pcg32;

/// Default number of cases per property (kept moderate: the repo has many
/// properties and CI is single-core).
pub const DEFAULT_CASES: u32 = 128;

/// Run `prop` over `cases` randomized cases. The property receives a fresh
/// deterministic RNG per case (seed derives from `seed` + case index) and
/// returns `Err(msg)` to signal failure.
pub fn check<F>(name: &str, seed: u64, cases: u32, mut prop: F)
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let mut rng = Pcg32::new(case_seed, case as u64 + 1);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (case_seed={case_seed:#x}): {msg}"
            );
        }
    }
}

/// Shorthand with [`DEFAULT_CASES`].
pub fn check_default<F>(name: &str, seed: u64, prop: F)
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    check(name, seed, DEFAULT_CASES, prop)
}

/// Assert helper: turn a boolean + context into the property result type.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check("tautology", 1, 64, |rng| {
            let x = rng.f32();
            prop_assert!((0.0..1.0).contains(&x), "x out of range: {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics_with_context() {
        check("always-false", 2, 8, |_| Err("nope".into()));
    }

    #[test]
    fn cases_are_deterministic_across_runs() {
        let mut first: Vec<u32> = Vec::new();
        check("collect", 3, 16, |rng| {
            first.push(rng.next_u32());
            Ok(())
        });
        let mut second: Vec<u32> = Vec::new();
        check("collect", 3, 16, |rng| {
            second.push(rng.next_u32());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
