//! Hashing tokenizer: words -> token ids in [1, VOCAB), 0 reserved for pad.
//!
//! Must agree with what the encoder artifact was compiled for: ids index a
//! VOCAB x EMBED_DIM table, 0 is the padding id and masks the position.
//! FNV-1a over lowercased word bytes, mod (VOCAB - 1) + 1 keeps ids dense
//! and never emits the pad id for a real token.

use super::{MAX_TOKENS, VOCAB};

/// FNV-1a 64-bit hash.
#[inline]
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Hash a word into a token id in [1, VOCAB).
#[inline]
pub fn hash_token(word: &str) -> i32 {
    let lower = word.to_ascii_lowercase();
    (fnv1a(lower.as_bytes()) % (VOCAB as u64 - 1)) as i32 + 1
}

/// Split a sentence into word tokens (alphanumeric runs; possessives and
/// hyphenated compounds split apart, which is fine for hashing purposes).
pub fn tokenize(sentence: &str) -> Vec<String> {
    let mut words = Vec::new();
    let mut cur = String::new();
    for c in sentence.chars() {
        if c.is_alphanumeric() {
            cur.push(c);
        } else if !cur.is_empty() {
            words.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        words.push(cur);
    }
    words
}

/// Sentence -> fixed-width row of hashed token ids, zero-padded/truncated
/// to MAX_TOKENS (the encoder artifact's static width).
#[derive(Debug, Default, Clone)]
pub struct Tokenizer;

impl Tokenizer {
    /// Tokenizer with the baked VOCAB/MAX_TOKENS dims.
    pub fn new() -> Self {
        Self
    }

    /// Hash-encode one sentence to a fixed token row (0-padded).
    pub fn encode_sentence(&self, sentence: &str) -> [i32; MAX_TOKENS] {
        let mut row = [0i32; MAX_TOKENS];
        for (i, w) in tokenize(sentence).iter().take(MAX_TOKENS).enumerate() {
            row[i] = hash_token(w);
        }
        row
    }

    /// Encode up to `max_rows` sentences into a row-major (rows x
    /// MAX_TOKENS) i32 buffer, zero rows for padding sentences.
    pub fn encode_batch(&self, sentences: &[String], max_rows: usize) -> Vec<i32> {
        assert!(
            sentences.len() <= max_rows,
            "{} sentences exceed batch {}",
            sentences.len(),
            max_rows
        );
        let mut out = vec![0i32; max_rows * MAX_TOKENS];
        for (i, s) in sentences.iter().enumerate() {
            out[i * MAX_TOKENS..(i + 1) * MAX_TOKENS].copy_from_slice(&self.encode_sentence(s));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_ids_in_range_never_pad() {
        for w in ["the", "a", "Reactor", "šum", "12345", "x"] {
            let id = hash_token(w);
            assert!((1..VOCAB as i32).contains(&id), "{w} -> {id}");
        }
    }

    #[test]
    fn hashing_case_insensitive_and_deterministic() {
        assert_eq!(hash_token("Energy"), hash_token("energy"));
        assert_eq!(hash_token("energy"), hash_token("energy"));
        assert_ne!(hash_token("energy"), hash_token("entropy"));
    }

    #[test]
    fn tokenize_splits_on_punctuation() {
        assert_eq!(
            tokenize("The cat, the dog — and 3.14!"),
            vec!["The", "cat", "the", "dog", "and", "3", "14"]
        );
    }

    #[test]
    fn encode_sentence_pads_and_truncates() {
        let t = Tokenizer::new();
        let row = t.encode_sentence("one two three");
        assert!(row[0] > 0 && row[1] > 0 && row[2] > 0);
        assert!(row[3..].iter().all(|&x| x == 0));

        let long = vec!["word"; 50].join(" ");
        let row = t.encode_sentence(&long);
        assert!(row.iter().all(|&x| x > 0));
    }

    #[test]
    fn encode_batch_layout() {
        let t = Tokenizer::new();
        let buf = t.encode_batch(&["alpha beta".into(), "gamma".into()], 4);
        assert_eq!(buf.len(), 4 * MAX_TOKENS);
        assert_eq!(buf[0], hash_token("alpha"));
        assert_eq!(buf[MAX_TOKENS], hash_token("gamma"));
        assert!(buf[2 * MAX_TOKENS..].iter().all(|&x| x == 0));
    }

    #[test]
    #[should_panic(expected = "exceed batch")]
    fn encode_batch_overflow_panics() {
        Tokenizer::new().encode_batch(&["a".into(), "b".into()], 1);
    }
}
