//! Abbreviation-aware sentence splitter.
//!
//! Splits on `.`, `!`, `?` followed by whitespace and an uppercase/digit
//! start, with guards for common abbreviations, initials ("J. Smith"),
//! decimal numbers ("3.14") and ellipses. Tuned for news-style prose (the
//! CNN/DailyMail register the paper evaluates on).

/// Abbreviations that never end a sentence, wherever they appear.
const ABBREVIATIONS: &[&str] = &[
    "mr", "mrs", "ms", "dr", "prof", "sr", "jr", "st", "vs", "etc", "inc",
    "ltd", "co", "corp", "gov", "gen", "sen", "rep", "capt", "sgt", "col",
    "lt", "maj", "dept", "univ", "assn", "approx", "u.s", "u.k", "e.g",
    "i.e", "a.m", "p.m",
];

/// Calendar/reference abbreviations that only bind when followed by a
/// digit ("Sat. 5th", "Fig. 3", "No. 7") — otherwise "The cat sat." would
/// never split because "sat" is also Saturday.
const ABBREVIATIONS_BEFORE_DIGIT: &[&str] = &[
    "fig", "eq", "no", "vol", "jan", "feb", "mar", "apr", "jun", "jul",
    "aug", "sep", "sept", "oct", "nov", "dec", "mon", "tue", "wed", "thu",
    "fri", "sat", "sun",
];

fn is_abbreviation(word: &str, next_is_digit: bool) -> bool {
    let w = word.trim_end_matches('.').to_ascii_lowercase();
    // single letters are initials ("J.")
    (w.len() == 1 && w.chars().all(|c| c.is_ascii_alphabetic()))
        || ABBREVIATIONS.contains(&w.as_str())
        || (next_is_digit && ABBREVIATIONS_BEFORE_DIGIT.contains(&w.as_str()))
}

/// Split text into trimmed, non-empty sentences.
pub fn split_sentences(text: &str) -> Vec<String> {
    let chars: Vec<char> = text.chars().collect();
    let mut sentences = Vec::new();
    let mut start = 0usize;
    let mut i = 0usize;

    while i < chars.len() {
        let c = chars[i];
        if c == '!' || c == '?' {
            // always terminal (news prose does not abbreviate with ! / ?)
            let end = i + 1;
            push_sentence(&chars[start..end], &mut sentences);
            start = end;
            i = end;
            continue;
        }
        if c == '.' {
            // ellipsis: consume the run of dots, treat as terminal
            let mut j = i;
            while j + 1 < chars.len() && chars[j + 1] == '.' {
                j += 1;
            }
            let dot_run = j - i + 1;
            let next_non_ws = chars[j + 1..]
                .iter()
                .position(|c| !c.is_whitespace())
                .map(|k| j + 1 + k);
            let followed_by_ws = j + 1 < chars.len() && chars[j + 1].is_whitespace();
            let next_starts_sentence = next_non_ws
                .map(|k| chars[k].is_uppercase() || chars[k].is_ascii_digit() || chars[k] == '"')
                .unwrap_or(true);

            // decimal number guard: digit.digit
            let decimal = dot_run == 1
                && i > 0
                && chars[i - 1].is_ascii_digit()
                && i + 1 < chars.len()
                && chars[i + 1].is_ascii_digit();

            // abbreviation guard: word before the dot
            let word_before: String = {
                let mut k = i;
                while k > 0 && (chars[k - 1].is_alphanumeric() || chars[k - 1] == '.') {
                    k -= 1;
                }
                chars[k..i].iter().collect()
            };

            let next_is_digit = next_non_ws
                .map(|k| chars[k].is_ascii_digit())
                .unwrap_or(false);
            let terminal = dot_run > 1
                || (!decimal
                    && followed_by_ws
                    && next_starts_sentence
                    && !is_abbreviation(&word_before, next_is_digit));

            if terminal {
                let end = j + 1;
                push_sentence(&chars[start..end], &mut sentences);
                start = end;
                i = end;
                continue;
            }
            i = j + 1;
            continue;
        }
        if c == '\n' && i + 1 < chars.len() && chars[i + 1] == '\n' {
            // paragraph break is always a boundary
            push_sentence(&chars[start..i], &mut sentences);
            start = i;
            i += 1;
            continue;
        }
        i += 1;
    }
    push_sentence(&chars[start..], &mut sentences);
    sentences
}

fn push_sentence(chars: &[char], out: &mut Vec<String>) {
    let s: String = chars.iter().collect::<String>().trim().to_string();
    // require some alphabetic content — drops stray punctuation fragments
    if s.chars().any(|c| c.is_alphabetic()) {
        out.push(s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_simple_sentences() {
        let s = split_sentences("The cat sat. The dog ran. Birds fly!");
        assert_eq!(s.len(), 3);
        assert_eq!(s[0], "The cat sat.");
        assert_eq!(s[2], "Birds fly!");
    }

    #[test]
    fn keeps_abbreviations_together() {
        let s = split_sentences("Dr. Smith arrived at 3 p.m. yesterday. He left.");
        assert_eq!(s.len(), 2, "{s:?}");
        assert!(s[0].starts_with("Dr. Smith"));
    }

    #[test]
    fn keeps_initials_together() {
        let s = split_sentences("J. K. Rowling wrote it. Everyone read it.");
        assert_eq!(s.len(), 2, "{s:?}");
    }

    #[test]
    fn keeps_decimals_together() {
        let s = split_sentences("Growth hit 3.14 percent. Markets rose.");
        assert_eq!(s.len(), 2, "{s:?}");
        assert!(s[0].contains("3.14"));
    }

    #[test]
    fn question_and_exclamation() {
        let s = split_sentences("Why did it happen? Nobody knows! The end.");
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn paragraph_break_splits() {
        let s = split_sentences("First paragraph ends here\n\nsecond one starts");
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn empty_and_punct_only_dropped() {
        assert!(split_sentences("").is_empty());
        assert!(split_sentences("... !!! ???").is_empty());
    }

    #[test]
    fn ellipsis_is_terminal() {
        let s = split_sentences("It went on... Then it stopped.");
        assert_eq!(s.len(), 2, "{s:?}");
    }

    #[test]
    fn quote_start_after_period() {
        let s = split_sentences("He said it plainly. \"We won,\" she replied.");
        assert_eq!(s.len(), 2, "{s:?}");
    }
}
