//! Text substrate: sentence splitting, tokenization, vocabulary hashing.
//!
//! The paper's pipeline consumes documents as sequences of sentences; the
//! encoder artifact consumes fixed-shape hashed-token matrices. This module
//! is the bridge. It is deliberately rule-based (no model downloads): an
//! abbreviation-aware splitter and an FNV-1a hashing tokenizer matching the
//! VOCAB/MAX_TOKENS constants baked into the AOT artifacts.

pub mod sentence;
pub mod tokenize;

pub use sentence::split_sentences;
pub use tokenize::{hash_token, tokenize, Tokenizer};

/// Static dims shared with python/compile/model.py. Changing either side
/// requires regenerating artifacts; runtime::artifacts cross-checks against
/// the manifest at load time.
pub const VOCAB: u32 = 4096;
/// Max tokens per sentence (artifact T dim).
pub const MAX_TOKENS: usize = 32;
/// Max sentences per document (artifact B dim).
pub const MAX_SENTENCES: usize = 128;
