//! Time-to-Solution / Energy-to-Solution (paper §V, Eqs. 14–16).
//!
//! TTS: runtime to reach a normalized objective >= threshold with
//! probability p_target, from the MLE of the per-iteration success
//! probability (geometric model):
//!
//! ```text
//! p = 1 / mean_k,  mean_k = mean over benchmarks of the first
//!                           iteration reaching the threshold     (Eq. 14)
//! TTS = ln(1 - p_target) / ln(1 - p) * mean(runtime)             (Eq. 15)
//! ETS = TTS_cobi * P_cobi + TTS_software * P_cpu                 (Eq. 16)
//! ```
//!
//! Runtimes come from a [`TimingModel`] holding the paper's published
//! hardware constants (COBI 200 µs @ 25 mW; Tabu 25 ms @ 20 W CPU;
//! objective evaluation 18.9 µs/iteration on the CPU) — our measured
//! wall-clock is reported alongside by the experiment drivers.

use crate::config::TimingConfig;

/// Per-solver timing/power model for one iteration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimingModel {
    /// Device (or CPU-solver) time per iteration, seconds.
    pub solve_time_s: f64,
    /// Device (or CPU) power during the solve, watts.
    pub solve_power_w: f64,
    /// CPU-side evaluation time per iteration (stochastic rounding +
    /// objective scoring), seconds.
    pub eval_time_s: f64,
    /// CPU power, watts.
    pub cpu_power_w: f64,
}

impl TimingModel {
    /// COBI: hardware solve + CPU evaluation per iteration.
    pub fn cobi(t: &TimingConfig, solve_time_s: f64, power_w: f64) -> Self {
        Self {
            solve_time_s,
            solve_power_w: power_w,
            eval_time_s: t.eval_time_s,
            cpu_power_w: t.cpu_power_w,
        }
    }

    /// Software solver on the CPU (evaluation folded into CPU work).
    pub fn software(t: &TimingConfig, solve_time_s: f64) -> Self {
        Self {
            solve_time_s,
            solve_power_w: t.cpu_power_w,
            eval_time_s: t.eval_time_s,
            cpu_power_w: t.cpu_power_w,
        }
    }

    /// Time per iteration (solve + evaluation).
    pub fn iter_time_s(&self) -> f64 {
        self.solve_time_s + self.eval_time_s
    }

    /// Energy per iteration (Eq. 16 integrand).
    pub fn iter_energy_j(&self) -> f64 {
        self.solve_time_s * self.solve_power_w + self.eval_time_s * self.cpu_power_w
    }
}

/// MLE of the per-iteration success probability (Eq. 14) from the first
/// success iteration per benchmark. Benchmarks that never succeeded are
/// censored at `max_iterations` (conservative: counted as k = max + 1).
pub fn success_probability(first_success: &[Option<usize>], max_iterations: usize) -> f64 {
    assert!(!first_success.is_empty());
    let ks: Vec<f64> = first_success
        .iter()
        .map(|k| match k {
            Some(k) => (*k).max(1) as f64,
            None => (max_iterations + 1) as f64,
        })
        .collect();
    let mean_k = ks.iter().sum::<f64>() / ks.len() as f64;
    (1.0 / mean_k).clamp(1e-9, 1.0)
}

/// Expected iterations to reach `p_target` under a geometric process
/// (the ln-ratio factor of Eq. 15).
pub fn iterations_to_target(p_success: f64, p_target: f64) -> f64 {
    assert!((0.0..1.0).contains(&p_target));
    if p_success >= 1.0 - 1e-12 {
        return 1.0;
    }
    ((1.0 - p_target).ln() / (1.0 - p_success).ln()).max(1.0)
}

/// TTS (Eq. 15) and ETS (Eq. 16) for one solver.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TtsEts {
    /// Per-iteration success probability.
    pub p_success: f64,
    /// Iterations to reach the target probability (Eq. 14).
    pub iterations: f64,
    /// Time-to-solution, seconds (Eq. 15).
    pub tts_s: f64,
    /// Energy-to-solution, joules (Eq. 16).
    pub ets_j: f64,
}

/// TTS/ETS of a solver with measured success rate `p_success` under `model`.
pub fn tts_ets(
    first_success: &[Option<usize>],
    max_iterations: usize,
    model: &TimingModel,
    p_target: f64,
) -> TtsEts {
    let p = success_probability(first_success, max_iterations);
    let iters = iterations_to_target(p, p_target);
    TtsEts {
        p_success: p,
        iterations: iters,
        tts_s: iters * model.iter_time_s(),
        ets_j: iters * model.iter_energy_j(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn timing() -> TimingConfig {
        TimingConfig::default()
    }

    #[test]
    fn mle_matches_eq14() {
        // k = [2, 4] -> k̄ = 3 -> p̂ = 1/3
        let p = success_probability(&[Some(2), Some(4)], 100);
        assert!((p - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn censoring_is_conservative() {
        let p_all = success_probability(&[Some(2), Some(2)], 100);
        let p_censored = success_probability(&[Some(2), None], 100);
        assert!(p_censored < p_all);
    }

    #[test]
    fn iterations_to_target_basics() {
        // p = 0.5, target 0.95: ln(0.05)/ln(0.5) ≈ 4.32
        let it = iterations_to_target(0.5, 0.95);
        assert!((it - 4.3219).abs() < 1e-3);
        // certain success -> one iteration
        assert_eq!(iterations_to_target(1.0, 0.95), 1.0);
        // target below single-run probability still costs one run
        assert_eq!(iterations_to_target(0.99, 0.5), 1.0);
    }

    #[test]
    fn cobi_vs_tabu_headline_ratio() {
        // identical success statistics: TTS ratio must equal the
        // iteration-time ratio; COBI (200 µs + 18.9 µs) vs Tabu
        // (25 ms + 18.9 µs) ≈ 114x per-iteration advantage
        let t = timing();
        let cobi = TimingModel::cobi(&t, 200e-6, 25e-3);
        let tabu = TimingModel::software(&t, 25e-3);
        let fs = vec![Some(3), Some(5), Some(4)];
        let a = tts_ets(&fs, 100, &cobi, t.p_target);
        let b = tts_ets(&fs, 100, &tabu, t.p_target);
        let ratio = b.tts_s / a.tts_s;
        assert!(ratio > 100.0, "tts ratio {ratio}");
        // energy: 3 orders of magnitude (paper abstract)
        let eratio = b.ets_j / a.ets_j;
        assert!(eratio > 500.0, "ets ratio {eratio}");
    }

    #[test]
    fn energy_model_matches_eq16() {
        let t = timing();
        let m = TimingModel::cobi(&t, 200e-6, 25e-3);
        // per iteration: 200µs·25mW + 18.9µs·20W
        let want = 200e-6 * 25e-3 + 18.9e-6 * 20.0;
        assert!((m.iter_energy_j() - want).abs() < 1e-12);
    }
}
