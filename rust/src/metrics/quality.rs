//! Summary-quality metrics: ROUGE-1/2/L against reference summaries.
//!
//! The paper's accuracy metric is the normalized Ising objective (Eq. 13,
//! in `ising::objective`); ROUGE here is the complementary *extrinsic*
//! check used by the examples and service to confirm that high normalized
//! objectives correspond to summaries overlapping the generator's
//! designated key-fact sentences.

use std::collections::HashMap;

use crate::text::tokenize;

fn grams(tokens: &[String], n: usize) -> HashMap<Vec<&str>, usize> {
    let mut map: HashMap<Vec<&str>, usize> = HashMap::new();
    if tokens.len() < n {
        return map;
    }
    for w in tokens.windows(n) {
        let key: Vec<&str> = w.iter().map(|s| s.as_str()).collect();
        *map.entry(key).or_insert(0) += 1;
    }
    map
}

fn lower_tokens(text: &str) -> Vec<String> {
    tokenize(text)
        .into_iter()
        .map(|w| w.to_ascii_lowercase())
        .collect()
}

/// ROUGE-N F1 between candidate and reference texts.
pub fn rouge_n(candidate: &str, reference: &str, n: usize) -> f64 {
    let ct = lower_tokens(candidate);
    let rt = lower_tokens(reference);
    let cg = grams(&ct, n);
    let rg = grams(&rt, n);
    let overlap: usize = rg
        .iter()
        .map(|(g, &rc)| rc.min(cg.get(g).copied().unwrap_or(0)))
        .sum();
    let c_total: usize = cg.values().sum();
    let r_total: usize = rg.values().sum();
    if c_total == 0 || r_total == 0 {
        return 0.0;
    }
    let p = overlap as f64 / c_total as f64;
    let r = overlap as f64 / r_total as f64;
    if p + r == 0.0 {
        0.0
    } else {
        2.0 * p * r / (p + r)
    }
}

/// Longest common subsequence length (token level).
fn lcs_len(a: &[String], b: &[String]) -> usize {
    let (n, m) = (a.len(), b.len());
    if n == 0 || m == 0 {
        return 0;
    }
    let mut prev = vec![0usize; m + 1];
    let mut cur = vec![0usize; m + 1];
    for i in 1..=n {
        for j in 1..=m {
            cur[j] = if a[i - 1] == b[j - 1] {
                prev[j - 1] + 1
            } else {
                prev[j].max(cur[j - 1])
            };
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[m]
}

/// ROUGE-L F1 (LCS-based).
pub fn rouge_l(candidate: &str, reference: &str) -> f64 {
    let ct = lower_tokens(candidate);
    let rt = lower_tokens(reference);
    let l = lcs_len(&ct, &rt) as f64;
    if ct.is_empty() || rt.is_empty() || l == 0.0 {
        return 0.0;
    }
    let p = l / ct.len() as f64;
    let r = l / rt.len() as f64;
    2.0 * p * r / (p + r)
}

/// Bundle of the three scores.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Rouge {
    /// ROUGE-1 F1 (unigram overlap).
    pub rouge1: f64,
    /// ROUGE-2 F1 (bigram overlap).
    pub rouge2: f64,
    /// ROUGE-L F1 (longest common subsequence).
    pub rouge_l: f64,
}

/// All three ROUGE scores of `candidate` against `reference`.
pub fn rouge_all(candidate: &str, reference: &str) -> Rouge {
    Rouge {
        rouge1: rouge_n(candidate, reference, 1),
        rouge2: rouge_n(candidate, reference, 2),
        rouge_l: rouge_l(candidate, reference),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_texts_score_one() {
        let t = "the quick brown fox jumps over the lazy dog";
        assert!((rouge_n(t, t, 1) - 1.0).abs() < 1e-12);
        assert!((rouge_n(t, t, 2) - 1.0).abs() < 1e-12);
        assert!((rouge_l(t, t) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn disjoint_texts_score_zero() {
        assert_eq!(rouge_n("alpha beta gamma", "delta epsilon zeta", 1), 0.0);
        assert_eq!(rouge_l("alpha beta", "delta epsilon"), 0.0);
    }

    #[test]
    fn rouge1_known_value() {
        // cand: {the, cat}, ref: {the, dog}: overlap 1, P=R=1/2, F1=1/2
        let f = rouge_n("the cat", "the dog", 1);
        assert!((f - 0.5).abs() < 1e-12);
    }

    #[test]
    fn rouge_l_respects_order() {
        // same bag of words, different order: L < 1
        let a = "one two three four";
        let b = "four three two one";
        assert!((rouge_n(a, b, 1) - 1.0).abs() < 1e-12);
        assert!(rouge_l(a, b) < 0.5);
    }

    #[test]
    fn case_insensitive() {
        assert!((rouge_n("The CAT", "the cat", 1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(rouge_n("", "the cat", 1), 0.0);
        assert_eq!(rouge_n("the cat", "", 2), 0.0);
        assert_eq!(rouge_l("", ""), 0.0);
    }
}
