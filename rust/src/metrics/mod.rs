//! Evaluation metrics: TTS/ETS models (Eqs. 14–16) and ROUGE quality.

pub mod quality;
pub mod tts;

pub use quality::{rouge_all, rouge_l, rouge_n, Rouge};
pub use tts::{iterations_to_target, success_probability, tts_ets, TimingModel, TtsEts};
