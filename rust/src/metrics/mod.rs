//! Evaluation metrics: TTS/ETS models (Eqs. 14–16) and ROUGE quality.
//!
//! `tts` implements the paper's time-to-solution / energy-to-solution
//! models — iterations to reach the target success probability (Eq. 14)
//! priced under a per-solver [`TimingModel`] (Eqs. 15–16); these drive
//! the Fig. 7/8 curves and the Table 1 projection. `quality` is the
//! in-tree ROUGE-1/2/L implementation scored against each synthetic
//! document's planted reference (the stand-in for the paper's ROUGE
//! columns — see DESIGN.md §Substitutions).

pub mod quality;
pub mod tts;

pub use quality::{rouge_all, rouge_l, rouge_n, Rouge};
pub use tts::{iterations_to_target, success_probability, tts_ets, TimingModel, TtsEts};
