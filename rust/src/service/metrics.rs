//! Service metrics: counts + streaming latency summary + per-stage
//! queue-wait histograms + snapshots of the device-pool counters and the
//! solver-portfolio telemetry (per-backend route counts, warm-start-cache
//! hit rates, per-backend latency histograms).
//!
//! Latencies are kept two ways: a bounded reservoir (uniform-ish by
//! decimation) for percentile reporting, and fixed log-spaced
//! [`Histogram`]s for cheap per-stage distribution tracking under
//! sustained load — both O(1) memory.

use std::time::Duration;

use crate::decompose::Strategy;
use crate::portfolio::PortfolioMetrics;
use crate::resilience::ResilienceMetrics;
use crate::sched::PoolMetrics;

const RESERVOIR: usize = 4096;

/// Per-decomposition-strategy completion counters, plus streaming-session
/// activity (sessions opened, chunks ingested, revisions served). One
/// block per service, updated on request completion / stream calls.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StrategyMetrics {
    /// Completed summaries decomposed with the sliding-window plan.
    pub window: u64,
    /// Completed summaries decomposed with the tree plan.
    pub tree: u64,
    /// Completed summaries produced by the streaming path (one-shot
    /// stream-strategy submits AND final stream-session summaries).
    pub stream: u64,
    /// `SUMMARIZE_STREAM` sessions opened.
    pub stream_sessions: u64,
    /// Chunks ingested across all stream sessions.
    pub stream_chunks: u64,
    /// Summary revisions served across all stream sessions.
    pub stream_revisions: u64,
}

impl StrategyMetrics {
    /// Count one completed summary under `strategy`.
    pub fn record(&mut self, strategy: Strategy) {
        match strategy {
            Strategy::Window => self.window += 1,
            Strategy::Tree => self.tree += 1,
            Strategy::Streaming => self.stream += 1,
        }
    }

    /// Total completed summaries across strategies.
    pub fn total(&self) -> u64 {
        self.window + self.tree + self.stream
    }

    /// One-line report fragment (empty when nothing was recorded).
    pub fn report(&self) -> String {
        let mut out = format!(
            "strategy window={} tree={} stream={}",
            self.window, self.tree, self.stream
        );
        if self.stream_sessions > 0 {
            out.push_str(&format!(
                " (sessions={} chunks={} revisions={})",
                self.stream_sessions, self.stream_chunks, self.stream_revisions
            ));
        }
        out
    }
}

/// Fixed-bucket histogram (seconds). Buckets are `bounds[i]`-bounded from
/// above, with one overflow bucket past the last bound.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    /// Upper bucket edges, ascending, seconds.
    bounds: Vec<f64>,
    /// bounds.len() + 1 counters (last = overflow).
    counts: Vec<u64>,
    count: u64,
    sum: f64,
}

impl Histogram {
    /// Histogram with explicit ascending bucket bounds (seconds).
    pub fn new(bounds: Vec<f64>) -> Self {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]));
        let counts = vec![0; bounds.len() + 1];
        Self {
            bounds,
            counts,
            count: 0,
            sum: 0.0,
        }
    }

    /// Log-spaced latency buckets: 10 µs .. 10 s.
    pub fn latency() -> Self {
        Self::new(vec![1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0])
    }

    /// Count one observation of `secs`.
    pub fn record(&mut self, secs: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| secs <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.count += 1;
        self.sum += secs;
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean observation (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Upper bound of the bucket containing quantile `q` (conservative;
    /// `f64::INFINITY` when it lands in the overflow bucket).
    pub fn quantile_bound(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target.max(1) {
                return self
                    .bounds
                    .get(i)
                    .copied()
                    .unwrap_or(f64::INFINITY);
            }
        }
        f64::INFINITY
    }

    /// (upper_bound_seconds, count) pairs, overflow last with `inf`.
    pub fn buckets(&self) -> Vec<(f64, u64)> {
        self.bounds
            .iter()
            .copied()
            .chain(std::iter::once(f64::INFINITY))
            .zip(self.counts.iter().copied())
            .collect()
    }

    /// Compact `n`/mean/p99 fragment.
    pub fn summary(&self) -> String {
        if self.count == 0 {
            return "n=0".into();
        }
        let p99 = self.quantile_bound(0.99);
        let p99s = if p99.is_finite() {
            format!("{:.2}ms", p99 * 1e3)
        } else {
            format!(">{:.0}s", self.bounds.last().copied().unwrap_or(0.0))
        };
        format!(
            "n={} mean={:.2}ms p99<={}",
            self.count,
            self.mean() * 1e3,
            p99s
        )
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::latency()
    }
}

/// Aggregate service counters, latency summaries, and subsystem snapshots.
#[derive(Debug, Clone, Default)]
pub struct ServiceMetrics {
    /// Requests accepted into the queue.
    pub submitted: u64,
    /// Requests completed successfully.
    pub completed: u64,
    /// Requests that errored.
    pub failed: u64,
    /// Requests rejected by backpressure.
    pub rejected: u64,
    /// Seconds spent queued (reservoir sample).
    queue_waits: Vec<f64>,
    /// Seconds spent solving (reservoir sample).
    solve_times: Vec<f64>,
    /// Per-stage distributions: service-queue wait and worker solve time.
    /// (The pool-queue wait histogram lives in [`PoolMetrics`].)
    pub queue_hist: Histogram,
    /// Worker solve-time distribution.
    pub solve_hist: Histogram,
    /// Per-strategy completions + streaming-session activity.
    pub strategies: StrategyMetrics,
    /// Device-pool snapshot (zero-valued when the pool is disabled).
    pub pool: PoolMetrics,
    /// Solver-portfolio snapshot: per-backend route counts, cache
    /// hit/warm/miss rates, per-backend latency histograms. `None` unless
    /// the pool backend is "portfolio".
    pub portfolio: Option<PortfolioMetrics>,
    /// Resilience snapshot: replication/vote/verify/retry/escalation
    /// counters, per-device calibrations and fault injections. `None`
    /// unless `[resilience]` (layer or fault model) is enabled.
    pub resilience: Option<ResilienceMetrics>,
}

impl ServiceMetrics {
    /// Record one request's queue wait and solve time.
    pub fn record_latency(&mut self, queue_wait: Duration, solve: Duration) {
        push_reservoir(&mut self.queue_waits, queue_wait.as_secs_f64());
        push_reservoir(&mut self.solve_times, solve.as_secs_f64());
        self.queue_hist.record(queue_wait.as_secs_f64());
        self.solve_hist.record(solve.as_secs_f64());
    }

    /// Reservoir-based percentile summary.
    pub fn latency_summary(&self) -> LatencySummary {
        LatencySummary {
            queue_p50: percentile(&self.queue_waits, 0.50),
            queue_p99: percentile(&self.queue_waits, 0.99),
            solve_p50: percentile(&self.solve_times, 0.50),
            solve_p99: percentile(&self.solve_times, 0.99),
        }
    }

    /// One-line operator report (counts, latencies, strategies, pool, portfolio).
    pub fn report(&self) -> String {
        let l = self.latency_summary();
        let mut out = format!(
            "submitted={} completed={} failed={} rejected={} | \
             queue p50={:.2}ms p99={:.2}ms | solve p50={:.2}ms p99={:.2}ms",
            self.submitted,
            self.completed,
            self.failed,
            self.rejected,
            l.queue_p50 * 1e3,
            l.queue_p99 * 1e3,
            l.solve_p50 * 1e3,
            l.solve_p99 * 1e3,
        );
        if self.strategies.total() > 0 || self.strategies.stream_sessions > 0 {
            out.push_str(" | ");
            out.push_str(&self.strategies.report());
        }
        if self.pool.devices > 0 {
            out.push_str(" | ");
            out.push_str(&self.pool.report());
        }
        if let Some(p) = &self.portfolio {
            out.push_str(" | ");
            out.push_str(&p.report());
        }
        if let Some(r) = &self.resilience {
            out.push_str(" | ");
            out.push_str(&r.report());
        }
        out
    }
}

/// Queue/solve latency percentiles, seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySummary {
    /// Median queue wait.
    pub queue_p50: f64,
    /// 99th-percentile queue wait.
    pub queue_p99: f64,
    /// Median solve time.
    pub solve_p50: f64,
    /// 99th-percentile solve time.
    pub solve_p99: f64,
}

fn push_reservoir(v: &mut Vec<f64>, x: f64) {
    if v.len() < RESERVOIR {
        v.push(x);
    } else {
        // cheap decimation: overwrite a pseudo-random slot derived from
        // the value count so long runs stay representative enough
        let idx = (v.len() as u64)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(x.to_bits()) as usize
            % RESERVOIR;
        v[idx] = x;
    }
}

fn percentile(v: &[f64], q: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let mut s = v.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    crate::util::stats::quantile_sorted(&s, q)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_percentiles() {
        let mut m = ServiceMetrics::default();
        for i in 1..=100 {
            m.record_latency(
                Duration::from_millis(i),
                Duration::from_millis(i * 2),
            );
        }
        let l = m.latency_summary();
        assert!((l.queue_p50 - 0.0505).abs() < 0.002, "{l:?}");
        assert!(l.solve_p50 > l.queue_p50);
        assert!(l.queue_p99 > l.queue_p50);
    }

    #[test]
    fn reservoir_is_bounded() {
        let mut m = ServiceMetrics::default();
        for i in 0..10_000 {
            m.record_latency(Duration::from_micros(i), Duration::from_micros(i));
        }
        assert!(m.queue_waits.len() <= RESERVOIR);
        assert!(m.solve_times.len() <= RESERVOIR);
        assert_eq!(m.queue_hist.count(), 10_000);
    }

    #[test]
    fn empty_metrics_report_zeroes() {
        let m = ServiceMetrics::default();
        let l = m.latency_summary();
        assert_eq!(l.queue_p50, 0.0);
        assert!(m.report().contains("submitted=0"));
        // pool line only appears when a pool exists
        assert!(!m.report().contains("occupancy"));
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        let mut h = Histogram::latency();
        for _ in 0..90 {
            h.record(0.5e-3); // <= 1ms bucket
        }
        for _ in 0..10 {
            h.record(0.5); // <= 1s bucket
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - (90.0 * 0.5e-3 + 10.0 * 0.5) / 100.0).abs() < 1e-12);
        assert_eq!(h.quantile_bound(0.50), 1e-3);
        assert_eq!(h.quantile_bound(0.99), 1.0);
        let buckets = h.buckets();
        assert_eq!(buckets.len(), 8);
        assert_eq!(buckets[2], (1e-3, 90));
        assert_eq!(buckets[5], (1.0, 10));
        assert!(h.summary().contains("n=100"), "{}", h.summary());
    }

    #[test]
    fn strategy_counters_surface_in_the_report() {
        let mut m = ServiceMetrics::default();
        assert!(!m.report().contains("strategy"), "empty metrics stay quiet");
        m.strategies.record(Strategy::Window);
        m.strategies.record(Strategy::Tree);
        m.strategies.record(Strategy::Tree);
        m.strategies.record(Strategy::Streaming);
        assert_eq!(m.strategies.total(), 4);
        let r = m.report();
        assert!(r.contains("strategy window=1 tree=2 stream=1"), "{r}");
        assert!(!r.contains("sessions"), "{r}");
        m.strategies.stream_sessions = 2;
        m.strategies.stream_chunks = 7;
        m.strategies.stream_revisions = 5;
        let r = m.report();
        assert!(r.contains("sessions=2 chunks=7 revisions=5"), "{r}");
    }

    #[test]
    fn resilience_counters_surface_in_the_report() {
        let mut m = ServiceMetrics::default();
        assert!(!m.report().contains("resilience"), "absent block stays quiet");
        m.resilience = Some(ResilienceMetrics {
            requests: 4,
            replica_solves: 12,
            vote_disagreements: 2,
            retries: 1,
            faults: crate::resilience::FaultStats {
                faulty_solves: 3,
                stuck_spins: 5,
                ..Default::default()
            },
            ..Default::default()
        });
        let report = m.report();
        assert!(report.contains("resilience: requests=4 replicas=12"), "{report}");
        assert!(report.contains("disagree=2"), "{report}");
        assert!(report.contains("faults solves=3 stuck=5"), "{report}");
    }

    #[test]
    fn histogram_overflow_bucket() {
        let mut h = Histogram::new(vec![1e-3, 1e-2]);
        h.record(5.0);
        assert!(h.quantile_bound(0.99).is_infinite());
        assert_eq!(h.buckets()[2].1, 1);
    }
}
