//! Service metrics: counts + streaming latency summary + per-stage
//! queue-wait histograms + snapshots of the device-pool counters and the
//! solver-portfolio telemetry (per-backend route counts, warm-start-cache
//! hit rates, per-backend latency histograms).
//!
//! Latencies are kept two ways: a bounded uniform reservoir (seeded
//! Algorithm R) for percentile reporting, and fixed log-spaced
//! [`Histogram`]s for cheap per-stage distribution tracking under
//! sustained load — both O(1) memory.

use std::time::Duration;

use anyhow::{ensure, Result};

use crate::decompose::Strategy;
use crate::obs::ObsMetrics;
use crate::portfolio::PortfolioMetrics;
use crate::resilience::ResilienceMetrics;
use crate::sched::{BreakerMetrics, PoolMetrics};
use crate::util::rng::Pcg32;

const RESERVOIR: usize = 4096;
/// RNG stream for the reservoirs' replacement draws — a metrics-private
/// stream, so sampling can never perturb any solver/quantizer RNG.
/// `pub(crate)` for the stream-id audit in `util::rng`.
pub(crate) const RESERVOIR_STREAM: u64 = 0xA160_0012;

/// Per-decomposition-strategy completion counters, plus streaming-session
/// activity (sessions opened, chunks ingested, revisions served). One
/// block per service, updated on request completion / stream calls.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StrategyMetrics {
    /// Completed summaries decomposed with the sliding-window plan.
    pub window: u64,
    /// Completed summaries decomposed with the tree plan.
    pub tree: u64,
    /// Completed summaries produced by the streaming path (one-shot
    /// stream-strategy submits AND final stream-session summaries).
    pub stream: u64,
    /// `SUMMARIZE_STREAM` sessions opened.
    pub stream_sessions: u64,
    /// Chunks ingested across all stream sessions.
    pub stream_chunks: u64,
    /// Summary revisions served across all stream sessions.
    pub stream_revisions: u64,
}

impl StrategyMetrics {
    /// Count one completed summary under `strategy`.
    pub fn record(&mut self, strategy: Strategy) {
        match strategy {
            Strategy::Window => self.window += 1,
            Strategy::Tree => self.tree += 1,
            Strategy::Streaming => self.stream += 1,
        }
    }

    /// Total completed summaries across strategies.
    pub fn total(&self) -> u64 {
        self.window + self.tree + self.stream
    }

    /// One-line report fragment (empty when nothing was recorded).
    pub fn report(&self) -> String {
        let mut out = format!(
            "strategy window={} tree={} stream={}",
            self.window, self.tree, self.stream
        );
        if self.stream_sessions > 0 {
            out.push_str(&format!(
                " (sessions={} chunks={} revisions={})",
                self.stream_sessions, self.stream_chunks, self.stream_revisions
            ));
        }
        out
    }
}

/// Per-workload completion counters for the k-of-n selection platform.
/// The ES counter absorbs legacy untagged submits (empty workload), and
/// the report fragment is gated on [`any_non_es`], so an ES-only
/// service's report stays byte-identical to a pre-platform build.
///
/// [`any_non_es`]: WorkloadMetrics::any_non_es
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkloadMetrics {
    /// Completed extractive-summarization requests (including legacy
    /// untagged submits).
    pub es: u64,
    /// Completed diverse-retrieval requests.
    pub retrieval: u64,
    /// Completed facility-dispersion requests.
    pub dispersion: u64,
}

impl WorkloadMetrics {
    /// Count one completed request under `workload` (`""` counts as ES;
    /// names outside the registry are ignored — the service validates
    /// workloads at admission, so none can complete).
    pub fn record(&mut self, workload: &str) {
        match workload {
            "" | "es" => self.es += 1,
            "retrieval" => self.retrieval += 1,
            "dispersion" => self.dispersion += 1,
            _ => {}
        }
    }

    /// Did any non-ES workload complete? Gates the report fragment.
    pub fn any_non_es(&self) -> bool {
        self.retrieval > 0 || self.dispersion > 0
    }

    /// One-line report fragment.
    pub fn report(&self) -> String {
        format!(
            "workload es={} retrieval={} dispersion={}",
            self.es, self.retrieval, self.dispersion
        )
    }
}

/// Overload-safety counters: deadline expiries, admission-control sheds,
/// contained worker panics and graceful-drain accounting. The block is
/// always present (not an `Option`) but all-zero under the defaults-off
/// config, and every report fragment is gated on [`any`], so a quiet
/// service's output stays byte-identical to a pre-overload build.
///
/// [`any`]: OverloadMetrics::any
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OverloadMetrics {
    /// Requests failed because their deadline expired before (or while
    /// queued for) solving.
    pub deadline_exceeded: u64,
    /// Batch-tier requests shed by admission control (`ERR RETRY`).
    pub shed_batch: u64,
    /// Interactive-tier requests shed (hard watermark, or a full queue
    /// while shedding is enabled).
    pub shed_interactive: u64,
    /// Worker solve calls that panicked — contained: the request failed,
    /// the worker kept serving.
    pub worker_panics: u64,
    /// Graceful drains begun (`::DRAIN::` frame or shutdown).
    pub drains: u64,
    /// In-flight requests still unfinished when a drain deadline expired.
    pub drain_aborted: u64,
}

impl OverloadMetrics {
    /// Did any overload machinery fire?
    pub fn any(&self) -> bool {
        self.deadline_exceeded > 0
            || self.shed_batch > 0
            || self.shed_interactive > 0
            || self.worker_panics > 0
            || self.drains > 0
            || self.drain_aborted > 0
    }

    /// One-line report fragment.
    pub fn report(&self) -> String {
        format!(
            "overload: deadline_exceeded={} shed_batch={} shed_interactive={} \
             worker_panics={} drains={} drain_aborted={}",
            self.deadline_exceeded,
            self.shed_batch,
            self.shed_interactive,
            self.worker_panics,
            self.drains,
            self.drain_aborted,
        )
    }
}

/// Fixed-bucket histogram (seconds). Buckets are `bounds[i]`-bounded from
/// above, with one overflow bucket past the last bound.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    /// Upper bucket edges, ascending, seconds.
    bounds: Vec<f64>,
    /// bounds.len() + 1 counters (last = overflow).
    counts: Vec<u64>,
    count: u64,
    sum: f64,
}

impl Histogram {
    /// Histogram with explicit ascending bucket bounds (seconds).
    pub fn new(bounds: Vec<f64>) -> Self {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]));
        let counts = vec![0; bounds.len() + 1];
        Self {
            bounds,
            counts,
            count: 0,
            sum: 0.0,
        }
    }

    /// Log-spaced latency buckets: 10 µs .. 10 s.
    pub fn latency() -> Self {
        Self::new(vec![1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0])
    }

    /// Count one observation of `secs`.
    pub fn record(&mut self, secs: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| secs <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.count += 1;
        self.sum += secs;
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean observation (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Upper bound of the bucket containing quantile `q` (conservative;
    /// `f64::INFINITY` when it lands in the overflow bucket).
    pub fn quantile_bound(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target.max(1) {
                return self
                    .bounds
                    .get(i)
                    .copied()
                    .unwrap_or(f64::INFINITY);
            }
        }
        f64::INFINITY
    }

    /// (upper_bound_seconds, count) pairs, overflow last with `inf`.
    pub fn buckets(&self) -> Vec<(f64, u64)> {
        self.bounds
            .iter()
            .copied()
            .chain(std::iter::once(f64::INFINITY))
            .zip(self.counts.iter().copied())
            .collect()
    }

    /// Sum of all observations (seconds).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Fold another histogram with IDENTICAL bucket bounds into this one
    /// (per-worker histograms aggregating into a fleet view). Errors —
    /// without modifying `self` — when the bounds differ.
    pub fn merge(&mut self, other: &Histogram) -> Result<()> {
        ensure!(
            self.bounds == other.bounds,
            "histogram bounds mismatch: {:?} vs {:?}",
            self.bounds,
            other.bounds
        );
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.count += other.count;
        self.sum += other.sum;
        Ok(())
    }

    /// Compact `n`/mean/p99 fragment.
    pub fn summary(&self) -> String {
        if self.count == 0 {
            return "n=0".into();
        }
        let p99 = self.quantile_bound(0.99);
        let p99s = if p99.is_finite() {
            format!("{:.2}ms", p99 * 1e3)
        } else {
            format!(">{:.0}s", self.bounds.last().copied().unwrap_or(0.0))
        };
        format!(
            "n={} mean={:.2}ms p99<={}",
            self.count,
            self.mean() * 1e3,
            p99s
        )
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::latency()
    }
}

/// Aggregate service counters, latency summaries, and subsystem snapshots.
#[derive(Debug, Clone, Default)]
pub struct ServiceMetrics {
    /// Requests accepted into the queue.
    pub submitted: u64,
    /// Requests completed successfully.
    pub completed: u64,
    /// Requests that errored.
    pub failed: u64,
    /// Requests rejected by backpressure.
    pub rejected: u64,
    /// Seconds spent queued (uniform reservoir sample).
    queue_waits: Reservoir,
    /// Seconds spent solving (uniform reservoir sample).
    solve_times: Reservoir,
    /// Per-stage distributions: service-queue wait and worker solve time.
    /// (The pool-queue wait histogram lives in [`PoolMetrics`].)
    pub queue_hist: Histogram,
    /// Worker solve-time distribution.
    pub solve_hist: Histogram,
    /// Per-strategy completions + streaming-session activity.
    pub strategies: StrategyMetrics,
    /// Per-workload completions (quiet in the report until a non-ES
    /// workload completes).
    pub workloads: WorkloadMetrics,
    /// Device-pool snapshot (zero-valued when the pool is disabled).
    pub pool: PoolMetrics,
    /// Solver-portfolio snapshot: per-backend route counts, cache
    /// hit/warm/miss rates, per-backend latency histograms. `None` unless
    /// the pool backend is "portfolio".
    pub portfolio: Option<PortfolioMetrics>,
    /// Resilience snapshot: replication/vote/verify/retry/escalation
    /// counters, per-device calibrations and fault injections. `None`
    /// unless `[resilience]` (layer or fault model) is enabled.
    pub resilience: Option<ResilienceMetrics>,
    /// Observability snapshot: trace-ring counters, slowest-request
    /// exemplars, the fleet energy ledger and dispatch-coalescing
    /// counters. `None` only on detached default blocks; a running
    /// `Service` always fills it.
    pub obs: Option<ObsMetrics>,
    /// Overload-safety counters (all-zero under the defaults-off config).
    pub overload: OverloadMetrics,
    /// Circuit-breaker fleet snapshot. `None` unless
    /// `[sched] breaker_enabled = true`.
    pub breaker: Option<BreakerMetrics>,
}

impl ServiceMetrics {
    /// Record one request's queue wait and solve time.
    pub fn record_latency(&mut self, queue_wait: Duration, solve: Duration) {
        self.queue_waits.push(queue_wait.as_secs_f64());
        self.solve_times.push(solve.as_secs_f64());
        self.queue_hist.record(queue_wait.as_secs_f64());
        self.solve_hist.record(solve.as_secs_f64());
    }

    /// Reservoir-based percentile summary.
    pub fn latency_summary(&self) -> LatencySummary {
        LatencySummary {
            queue_p50: percentile(self.queue_waits.samples(), 0.50),
            queue_p99: percentile(self.queue_waits.samples(), 0.99),
            solve_p50: percentile(self.solve_times.samples(), 0.50),
            solve_p99: percentile(self.solve_times.samples(), 0.99),
        }
    }

    /// One-line operator report (counts, latencies, strategies, pool, portfolio).
    pub fn report(&self) -> String {
        let l = self.latency_summary();
        let mut out = format!(
            "submitted={} completed={} failed={} rejected={} | \
             queue p50={:.2}ms p99={:.2}ms | solve p50={:.2}ms p99={:.2}ms",
            self.submitted,
            self.completed,
            self.failed,
            self.rejected,
            l.queue_p50 * 1e3,
            l.queue_p99 * 1e3,
            l.solve_p50 * 1e3,
            l.solve_p99 * 1e3,
        );
        if self.strategies.total() > 0 || self.strategies.stream_sessions > 0 {
            out.push_str(" | ");
            out.push_str(&self.strategies.report());
        }
        if self.workloads.any_non_es() {
            out.push_str(" | ");
            out.push_str(&self.workloads.report());
        }
        if self.pool.devices > 0 {
            out.push_str(" | ");
            out.push_str(&self.pool.report());
        }
        if let Some(p) = &self.portfolio {
            out.push_str(" | ");
            out.push_str(&p.report());
        }
        if let Some(r) = &self.resilience {
            out.push_str(" | ");
            out.push_str(&r.report());
        }
        if let Some(o) = &self.obs {
            if o.any() {
                out.push_str(" | ");
                out.push_str(&o.report());
            }
        }
        if self.overload.any() {
            out.push_str(" | ");
            out.push_str(&self.overload.report());
        }
        if let Some(b) = &self.breaker {
            if b.any() {
                out.push_str(" | ");
                out.push_str(&b.report());
            }
        }
        out
    }
}

/// Queue/solve latency percentiles, seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySummary {
    /// Median queue wait.
    pub queue_p50: f64,
    /// 99th-percentile queue wait.
    pub queue_p99: f64,
    /// Median solve time.
    pub solve_p50: f64,
    /// 99th-percentile solve time.
    pub solve_p99: f64,
}

/// Bounded uniform sample of a latency stream: Vitter's Algorithm R
/// with a seeded metrics-private [`Pcg32`]. After `seen` observations,
/// every observation is retained with probability `RESERVOIR / seen`
/// exactly (the previous decimation scheme keyed replacement slots to
/// the value bits, which biased long runs toward early samples). The
/// uniform index draw maps 64 random bits onto `[0, seen)` by widening
/// multiply — bias is at most 2⁻⁶⁴ per draw.
#[derive(Debug, Clone)]
struct Reservoir {
    samples: Vec<f64>,
    seen: u64,
    rng: Pcg32,
}

impl Default for Reservoir {
    fn default() -> Self {
        Self {
            samples: Vec::new(),
            seen: 0,
            rng: Pcg32::new(0x5EED_0B5, RESERVOIR_STREAM),
        }
    }
}

impl Reservoir {
    fn push(&mut self, x: f64) {
        self.seen += 1;
        if self.samples.len() < RESERVOIR {
            self.samples.push(x);
        } else {
            let j = ((self.rng.next_u64() as u128 * self.seen as u128) >> 64) as usize;
            if j < RESERVOIR {
                self.samples[j] = x;
            }
        }
    }

    fn samples(&self) -> &[f64] {
        &self.samples
    }

    fn len(&self) -> usize {
        self.samples.len()
    }
}

fn percentile(v: &[f64], q: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let mut s = v.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    crate::util::stats::quantile_sorted(&s, q)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_percentiles() {
        let mut m = ServiceMetrics::default();
        for i in 1..=100 {
            m.record_latency(
                Duration::from_millis(i),
                Duration::from_millis(i * 2),
            );
        }
        let l = m.latency_summary();
        assert!((l.queue_p50 - 0.0505).abs() < 0.002, "{l:?}");
        assert!(l.solve_p50 > l.queue_p50);
        assert!(l.queue_p99 > l.queue_p50);
    }

    #[test]
    fn reservoir_is_bounded() {
        let mut m = ServiceMetrics::default();
        for i in 0..10_000 {
            m.record_latency(Duration::from_micros(i), Duration::from_micros(i));
        }
        assert!(m.queue_waits.len() <= RESERVOIR);
        assert!(m.solve_times.len() <= RESERVOIR);
        assert_eq!(m.queue_hist.count(), 10_000);
    }

    #[test]
    fn reservoir_sampling_is_uniform_over_the_stream() {
        // feed a monotone stream much longer than the reservoir: a
        // uniform sample has mean/median near the stream midpoint and
        // every quarter of the stream proportionally represented (the
        // retired decimation scheme failed all three)
        let n = 100_000u64;
        let mut r = Reservoir::default();
        for i in 0..n {
            r.push(i as f64);
        }
        assert_eq!(r.len(), RESERVOIR);
        let mean = r.samples().iter().sum::<f64>() / RESERVOIR as f64;
        // sd of the sample mean ≈ (n/√12)/√4096 ≈ 451; 2000 ≈ 4.4σ
        assert!((mean - 50_000.0).abs() < 2_000.0, "mean={mean}");
        let mut s = r.samples().to_vec();
        s.sort_by(|a, b| a.total_cmp(b));
        let median = s[RESERVOIR / 2];
        assert!((median - 50_000.0).abs() < 4_000.0, "median={median}");
        // the first stream quarter holds ≈ RESERVOIR/4 samples
        // (binomial sd ≈ 28; 200 ≈ 7σ)
        let early = s.iter().filter(|&&x| x < 25_000.0).count() as f64;
        assert!((early - 1_024.0).abs() < 200.0, "early={early}");
        // seeded: a second identical stream samples identically
        let mut r2 = Reservoir::default();
        for i in 0..n {
            r2.push(i as f64);
        }
        assert_eq!(r.samples(), r2.samples());
    }

    #[test]
    fn empty_metrics_report_zeroes() {
        let m = ServiceMetrics::default();
        let l = m.latency_summary();
        assert_eq!(l.queue_p50, 0.0);
        assert!(m.report().contains("submitted=0"));
        // pool line only appears when a pool exists
        assert!(!m.report().contains("occupancy"));
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        let mut h = Histogram::latency();
        for _ in 0..90 {
            h.record(0.5e-3); // <= 1ms bucket
        }
        for _ in 0..10 {
            h.record(0.5); // <= 1s bucket
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - (90.0 * 0.5e-3 + 10.0 * 0.5) / 100.0).abs() < 1e-12);
        assert_eq!(h.quantile_bound(0.50), 1e-3);
        assert_eq!(h.quantile_bound(0.99), 1.0);
        let buckets = h.buckets();
        assert_eq!(buckets.len(), 8);
        assert_eq!(buckets[2], (1e-3, 90));
        assert_eq!(buckets[5], (1.0, 10));
        assert!(h.summary().contains("n=100"), "{}", h.summary());
    }

    #[test]
    fn strategy_counters_surface_in_the_report() {
        let mut m = ServiceMetrics::default();
        assert!(!m.report().contains("strategy"), "empty metrics stay quiet");
        m.strategies.record(Strategy::Window);
        m.strategies.record(Strategy::Tree);
        m.strategies.record(Strategy::Tree);
        m.strategies.record(Strategy::Streaming);
        assert_eq!(m.strategies.total(), 4);
        let r = m.report();
        assert!(r.contains("strategy window=1 tree=2 stream=1"), "{r}");
        assert!(!r.contains("sessions"), "{r}");
        m.strategies.stream_sessions = 2;
        m.strategies.stream_chunks = 7;
        m.strategies.stream_revisions = 5;
        let r = m.report();
        assert!(r.contains("sessions=2 chunks=7 revisions=5"), "{r}");
    }

    #[test]
    fn workload_counters_stay_quiet_until_a_non_es_workload_completes() {
        let mut m = ServiceMetrics::default();
        m.workloads.record("");
        m.workloads.record("es");
        assert_eq!(m.workloads.es, 2, "empty tag counts as ES");
        assert!(!m.workloads.any_non_es());
        assert!(!m.report().contains("workload"), "ES-only report stays quiet");
        m.workloads.record("retrieval");
        m.workloads.record("dispersion");
        m.workloads.record("dispersion");
        m.workloads.record("not-registered");
        let r = m.report();
        assert!(r.contains("workload es=2 retrieval=1 dispersion=2"), "{r}");
    }

    #[test]
    fn resilience_counters_surface_in_the_report() {
        let mut m = ServiceMetrics::default();
        assert!(!m.report().contains("resilience"), "absent block stays quiet");
        m.resilience = Some(ResilienceMetrics {
            requests: 4,
            replica_solves: 12,
            vote_disagreements: 2,
            retries: 1,
            faults: crate::resilience::FaultStats {
                faulty_solves: 3,
                stuck_spins: 5,
                ..Default::default()
            },
            ..Default::default()
        });
        let report = m.report();
        assert!(report.contains("resilience: requests=4 replicas=12"), "{report}");
        assert!(report.contains("disagree=2"), "{report}");
        assert!(report.contains("faults solves=3 stuck=5"), "{report}");
    }

    #[test]
    fn overload_and_breaker_blocks_stay_quiet_until_they_fire() {
        let mut m = ServiceMetrics::default();
        assert!(!m.overload.any());
        assert!(!m.report().contains("overload:"), "quiet block must not print");
        assert!(!m.report().contains("breaker:"), "absent block must not print");
        m.overload.shed_batch = 3;
        m.overload.drains = 1;
        let r = m.report();
        assert!(r.contains("overload:"), "{r}");
        assert!(r.contains("shed_batch=3"), "{r}");
        // a breaker snapshot with no activity also stays quiet
        m.breaker = Some(BreakerMetrics {
            devices: 2,
            ..Default::default()
        });
        assert!(!m.report().contains("breaker:"), "{}", m.report());
        m.breaker = Some(BreakerMetrics {
            devices: 2,
            open: 1,
            trips: 4,
            probes: 2,
            readmissions: 1,
            ..Default::default()
        });
        let r = m.report();
        assert!(r.contains("breaker: 1/2 open"), "{r}");
        assert!(r.contains("4 trips"), "{r}");
    }

    #[test]
    fn histogram_overflow_bucket() {
        let mut h = Histogram::new(vec![1e-3, 1e-2]);
        h.record(5.0);
        assert!(h.quantile_bound(0.99).is_infinite());
        assert_eq!(h.buckets()[2].1, 1);
    }

    #[test]
    fn histogram_bucket_edges_are_inclusive_above() {
        // `secs <= bound` places an exact-edge observation in the bucket
        // it bounds, and the next representable value in the one after
        let mut h = Histogram::new(vec![1e-3, 1e-2, 1e-1]);
        h.record(1e-3); // exactly the first edge
        h.record(f64::from_bits(1e-3f64.to_bits() + 1)); // just above
        h.record(1e-1); // exactly the last edge
        let buckets = h.buckets();
        assert_eq!(buckets[0], (1e-3, 1));
        assert_eq!(buckets[1], (1e-2, 1));
        assert_eq!(buckets[2], (1e-1, 1));
        assert_eq!(buckets[3], (f64::INFINITY, 0));
        // zero and negative-ish underflow both land in the first bucket
        h.record(0.0);
        assert_eq!(h.buckets()[0].1, 2);
    }

    #[test]
    fn histogram_merge_sums_counts_and_moments() {
        let mut a = Histogram::latency();
        let mut b = Histogram::latency();
        for _ in 0..5 {
            a.record(0.5e-3);
        }
        for _ in 0..3 {
            b.record(0.5);
        }
        b.record(100.0); // overflow
        a.merge(&b).unwrap();
        assert_eq!(a.count(), 9);
        assert!((a.sum() - (5.0 * 0.5e-3 + 3.0 * 0.5 + 100.0)).abs() < 1e-12);
        let buckets = a.buckets();
        assert_eq!(buckets[2].1, 5, "<=1ms bucket");
        assert_eq!(buckets[5].1, 3, "<=1s bucket");
        assert_eq!(buckets[7].1, 1, "overflow bucket");
        // b is untouched
        assert_eq!(b.count(), 4);
    }

    #[test]
    fn histogram_merge_rejects_mismatched_bounds() {
        let mut a = Histogram::new(vec![1e-3, 1e-2]);
        let b = Histogram::new(vec![1e-3, 2e-2]);
        assert!(a.merge(&b).is_err());
        assert_eq!(a.count(), 0, "failed merge must not modify the target");
    }

    #[test]
    fn histogram_quantiles_estimate_from_buckets() {
        let mut h = Histogram::latency();
        for _ in 0..50 {
            h.record(5e-5); // <= 1e-4
        }
        for _ in 0..45 {
            h.record(5e-3); // <= 1e-2
        }
        for _ in 0..5 {
            h.record(5.0); // <= 10
        }
        assert_eq!(h.quantile_bound(0.0), 1e-4, "q=0 is the first bucket");
        assert_eq!(h.quantile_bound(0.50), 1e-4);
        assert_eq!(h.quantile_bound(0.51), 1e-2);
        assert_eq!(h.quantile_bound(0.95), 1e-2);
        assert_eq!(h.quantile_bound(0.96), 10.0);
        assert_eq!(h.quantile_bound(1.0), 10.0);
        // quantile bounds are monotone in q
        let qs = [0.0, 0.1, 0.5, 0.9, 0.99, 1.0];
        for w in qs.windows(2) {
            assert!(h.quantile_bound(w[0]) <= h.quantile_bound(w[1]));
        }
    }

    #[test]
    fn obs_snapshot_surfaces_in_the_report() {
        let mut m = ServiceMetrics::default();
        assert!(!m.report().contains("obs:"), "absent block stays quiet");
        m.obs = Some(ObsMetrics::default());
        assert!(!m.report().contains("obs:"), "empty block stays quiet");
        m.obs = Some(ObsMetrics {
            recorded: 2,
            exemplars: vec![crate::obs::Exemplar {
                doc: "doc-a".into(),
                secs: 0.25,
            }],
            ..Default::default()
        });
        let r = m.report();
        assert!(r.contains("obs: traces=2"), "{r}");
        assert!(r.contains("slowest=[doc-a:250.0ms]"), "{r}");
    }
}
