//! Service metrics: counts + streaming latency summary.
//!
//! Latencies are kept in a bounded reservoir (uniform-ish by decimation)
//! so percentile reporting stays O(1) memory under sustained load.

use std::time::Duration;

const RESERVOIR: usize = 4096;

#[derive(Debug, Clone, Default)]
pub struct ServiceMetrics {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub rejected: u64,
    /// Seconds spent queued (reservoir sample).
    queue_waits: Vec<f64>,
    /// Seconds spent solving (reservoir sample).
    solve_times: Vec<f64>,
}

impl ServiceMetrics {
    pub fn record_latency(&mut self, queue_wait: Duration, solve: Duration) {
        push_reservoir(&mut self.queue_waits, queue_wait.as_secs_f64());
        push_reservoir(&mut self.solve_times, solve.as_secs_f64());
    }

    pub fn latency_summary(&self) -> LatencySummary {
        LatencySummary {
            queue_p50: percentile(&self.queue_waits, 0.50),
            queue_p99: percentile(&self.queue_waits, 0.99),
            solve_p50: percentile(&self.solve_times, 0.50),
            solve_p99: percentile(&self.solve_times, 0.99),
        }
    }

    pub fn report(&self) -> String {
        let l = self.latency_summary();
        format!(
            "submitted={} completed={} failed={} rejected={} | \
             queue p50={:.2}ms p99={:.2}ms | solve p50={:.2}ms p99={:.2}ms",
            self.submitted,
            self.completed,
            self.failed,
            self.rejected,
            l.queue_p50 * 1e3,
            l.queue_p99 * 1e3,
            l.solve_p50 * 1e3,
            l.solve_p99 * 1e3,
        )
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySummary {
    pub queue_p50: f64,
    pub queue_p99: f64,
    pub solve_p50: f64,
    pub solve_p99: f64,
}

fn push_reservoir(v: &mut Vec<f64>, x: f64) {
    if v.len() < RESERVOIR {
        v.push(x);
    } else {
        // cheap decimation: overwrite a pseudo-random slot derived from
        // the value count so long runs stay representative enough
        let idx = (v.len() as u64)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(x.to_bits()) as usize
            % RESERVOIR;
        v[idx] = x;
    }
}

fn percentile(v: &[f64], q: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let mut s = v.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    crate::util::stats::quantile_sorted(&s, q)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_percentiles() {
        let mut m = ServiceMetrics::default();
        for i in 1..=100 {
            m.record_latency(
                Duration::from_millis(i),
                Duration::from_millis(i * 2),
            );
        }
        let l = m.latency_summary();
        assert!((l.queue_p50 - 0.0505).abs() < 0.002, "{l:?}");
        assert!(l.solve_p50 > l.queue_p50);
        assert!(l.queue_p99 > l.queue_p50);
    }

    #[test]
    fn reservoir_is_bounded() {
        let mut m = ServiceMetrics::default();
        for i in 0..10_000 {
            m.record_latency(Duration::from_micros(i), Duration::from_micros(i));
        }
        assert!(m.queue_waits.len() <= RESERVOIR);
        assert!(m.solve_times.len() <= RESERVOIR);
    }

    #[test]
    fn empty_metrics_report_zeroes() {
        let m = ServiceMetrics::default();
        let l = m.latency_summary();
        assert_eq!(l.queue_p50, 0.0);
        assert!(m.report().contains("submitted=0"));
    }
}
