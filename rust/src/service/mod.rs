//! Edge summarization service — the deployment shape the paper's
//! conclusion targets ("real-time, low-power summarization engines in
//! edge devices").
//!
//! Architecture (threads + channels; no tokio in the offline vendor set):
//!
//!   clients ──> Router (bounded queue, backpressure) ──> worker pool
//!                                                        each worker owns
//!                                                        an EsPipeline +
//!                                                        COBI device
//!
//! The router batches queued requests up to `max_batch` per dispatch (one
//! channel send per batch, amortizing wakeups), rejects when the queue is
//! full, and aggregates latency/throughput metrics.
//!
//! Ising solves route through the shared `sched::DevicePool` by default
//! (pool-capable solvers: cobi/tabu/sa, or the adaptive "portfolio"
//! backend when `[portfolio] enabled = true`), so subproblems from ALL
//! in-flight documents coalesce into batched device dispatches; workers
//! fall back to private solvers for brute/exact/random or when
//! `[sched] enabled = false`. See DESIGN.md §Sched and §Portfolio.

pub mod metrics;
pub mod tcp;
pub mod worker;

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::Settings;
use crate::corpus::Document;
use crate::pipeline::Summary;
use crate::runtime::ArtifactRuntime;
use crate::sched::{self, DevicePool};

pub use metrics::ServiceMetrics;
use worker::{spawn_workers, Job, SolveRoute};

/// Rejected-due-to-backpressure error marker.
#[derive(Debug, thiserror::Error)]
#[error("service queue full (backpressure): retry later")]
pub struct Overloaded;

/// Client-side handle for one submitted request.
pub struct Ticket {
    pub id: u64,
    rx: Receiver<Result<Summary>>,
    submitted: Instant,
}

impl Ticket {
    /// Block until the summary is ready.
    pub fn wait(self) -> Result<Summary> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => bail!("service dropped the request (shutdown?)"),
        }
    }

    pub fn elapsed(&self) -> std::time::Duration {
        self.submitted.elapsed()
    }
}

/// The running service.
pub struct Service {
    tx: SyncSender<Job>,
    metrics: Arc<Mutex<ServiceMetrics>>,
    inflight: Arc<AtomicUsize>,
    next_id: AtomicUsize,
    stop: Arc<AtomicBool>,
    workers: Vec<std::thread::JoinHandle<()>>,
    queue_depth: usize,
    /// Shared solve pool (None when running worker-private solvers).
    pool: Option<DevicePool>,
}

impl Service {
    /// Start the worker pool per `settings.service` (+ the shared device
    /// pool per `settings.sched` when enabled and solver-compatible).
    pub fn start(settings: &Settings) -> Result<Self> {
        Self::start_with(settings, None)
    }

    /// As [`Service::start`], with an artifact runtime for the COBI-HLO
    /// pool backend.
    pub fn start_with(settings: &Settings, rt: Option<&ArtifactRuntime>) -> Result<Self> {
        let metrics = Arc::new(Mutex::new(ServiceMetrics::default()));
        let inflight = Arc::new(AtomicUsize::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = sync_channel::<Job>(settings.service.queue_depth);

        let pool = if sched::service_pooled(settings) {
            Some(DevicePool::start(settings, rt)?)
        } else {
            None
        };
        let route = match &pool {
            Some(p) => SolveRoute::Pooled(p.handle()),
            None => SolveRoute::Local,
        };

        let workers = spawn_workers(
            settings,
            rx,
            metrics.clone(),
            inflight.clone(),
            stop.clone(),
            route,
            rt,
        )?;
        Ok(Self {
            tx,
            metrics,
            inflight,
            next_id: AtomicUsize::new(1),
            stop,
            workers,
            queue_depth: settings.service.queue_depth,
            pool,
        })
    }

    /// Submit a document; non-blocking. Errors with [`Overloaded`] when
    /// the queue is full (backpressure) instead of buffering unboundedly.
    pub fn submit(&self, doc: Document) -> Result<Ticket> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) as u64;
        let (otx, orx) = sync_channel(1);
        let job = Job {
            id,
            doc,
            respond: otx,
            enqueued: Instant::now(),
        };
        match self.tx.try_send(job) {
            Ok(()) => {
                self.inflight.fetch_add(1, Ordering::Relaxed);
                self.metrics.lock().unwrap().submitted += 1;
                Ok(Ticket {
                    id,
                    rx: orx,
                    submitted: Instant::now(),
                })
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.lock().unwrap().rejected += 1;
                Err(Overloaded.into())
            }
            Err(TrySendError::Disconnected(_)) => bail!("service stopped"),
        }
    }

    /// Requests currently queued or executing.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    pub fn queue_depth(&self) -> usize {
        self.queue_depth
    }

    /// Metrics snapshot, including the device-pool counters (and, when
    /// the pool hosts the solver portfolio, its route/cache telemetry).
    pub fn metrics(&self) -> ServiceMetrics {
        let mut m = self.metrics.lock().unwrap().clone();
        if let Some(pool) = &self.pool {
            m.pool = pool.metrics();
            m.portfolio = pool.portfolio_metrics();
        }
        m
    }

    /// True when Ising solves route through the shared device pool.
    pub fn is_pooled(&self) -> bool {
        self.pool.is_some()
    }

    /// Graceful shutdown: stop accepting, drain workers, then the pool.
    pub fn shutdown(self) {
        self.stop.store(true, Ordering::SeqCst);
        drop(self.tx); // closes the queue; workers exit after draining
        for w in self.workers {
            let _ = w.join();
        }
        // workers dropped their PoolHandles on exit; the pool's own
        // sender is the last one, so device threads drain and join here
        if let Some(pool) = self.pool {
            pool.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::benchmark_set;

    fn test_settings() -> Settings {
        let mut s = Settings::default();
        s.service.workers = 2;
        s.service.queue_depth = 8;
        s.pipeline.solver = "tabu".into();
        s.pipeline.iterations = 2;
        s.pipeline.summary_len = 3;
        s
    }

    #[test]
    fn serves_requests_end_to_end() {
        let settings = test_settings();
        let svc = Service::start(&settings).unwrap();
        let set = benchmark_set("bench_10").unwrap();
        let tickets: Vec<Ticket> = set.documents[..4]
            .iter()
            .map(|d| svc.submit(d.clone()).unwrap())
            .collect();
        for t in tickets {
            let s = t.wait().unwrap();
            assert_eq!(s.selected.len(), 3);
        }
        let m = svc.metrics();
        assert_eq!(m.submitted, 4);
        assert_eq!(m.completed, 4);
        assert_eq!(m.failed, 0);
        svc.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let mut settings = test_settings();
        settings.service.workers = 1;
        settings.service.queue_depth = 1;
        settings.pipeline.iterations = 10; // slow enough to pile up
        let svc = Service::start(&settings).unwrap();
        let set = benchmark_set("cnn_dm_20").unwrap();
        let mut accepted = 0usize;
        let mut rejected = 0usize;
        let mut tickets = Vec::new();
        for d in &set.documents {
            match svc.submit(d.clone()) {
                Ok(t) => {
                    accepted += 1;
                    tickets.push(t);
                }
                Err(_) => rejected += 1,
            }
        }
        assert!(rejected > 0, "no backpressure observed");
        for t in tickets {
            let _ = t.wait();
        }
        assert_eq!(svc.metrics().rejected as usize, rejected);
        let _ = accepted;
        svc.shutdown();
    }

    #[test]
    fn shutdown_joins_workers() {
        let svc = Service::start(&test_settings()).unwrap();
        svc.shutdown(); // must not hang
    }

    #[test]
    fn pooled_route_is_default_and_reports_occupancy() {
        let mut settings = test_settings();
        settings.service.workers = 4;
        settings.sched.devices = 2;
        settings.sched.linger_us = 2_000;
        let svc = Service::start(&settings).unwrap();
        assert!(svc.is_pooled());
        let set = benchmark_set("bench_10").unwrap();
        let tickets: Vec<Ticket> = set
            .documents
            .iter()
            .map(|d| svc.submit(d.clone()).unwrap())
            .collect();
        for t in tickets {
            assert_eq!(t.wait().unwrap().selected.len(), 3);
        }
        let m = svc.metrics();
        assert_eq!(m.completed, 20);
        // bench_10 docs are single-stage: one pool request per document,
        // `iterations` instances per request
        assert_eq!(m.pool.requests, 20);
        assert_eq!(
            m.pool.instances,
            20 * settings.pipeline.iterations as u64
        );
        assert!(m.pool.dispatches >= 1);
        // occupancy > 1 here certifies instance-level amortization (each
        // request carries `iterations` instances); cross-document request
        // fusion is timing-dependent under test load, so coalescing() > 1
        // is pinned by the dedicated pool test instead
        // (sched::pool::tests::concurrent_clients_coalesce)
        assert!(
            m.pool.batch_occupancy() > 1.0,
            "occupancy {} not > 1",
            m.pool.batch_occupancy()
        );
        assert_eq!(m.pool.queue_wait.count(), 20);
        assert!(m.queue_hist.count() >= 20);
        assert!(m.report().contains("occupancy"));
        svc.shutdown();
    }

    #[test]
    fn portfolio_route_surfaces_telemetry_in_service_metrics() {
        let mut settings = test_settings();
        settings.portfolio.enabled = true; // static cobi + warm cache
        let svc = Service::start(&settings).unwrap();
        assert!(svc.is_pooled());
        let set = benchmark_set("bench_10").unwrap();
        // first wave populates the fleet-wide cache...
        let tickets: Vec<Ticket> = set
            .documents
            .iter()
            .map(|d| svc.submit(d.clone()).unwrap())
            .collect();
        for t in tickets {
            assert_eq!(t.wait().unwrap().selected.len(), 3);
        }
        // ...an identical second wave (same doc ids => same doc seeds =>
        // identical quantized instances) must exact-hit it
        let tickets: Vec<Ticket> = set
            .documents
            .iter()
            .map(|d| svc.submit(d.clone()).unwrap())
            .collect();
        for t in tickets {
            assert_eq!(t.wait().unwrap().selected.len(), 3);
        }
        let m = svc.metrics();
        assert_eq!(m.completed, 40);
        let p = m.portfolio.expect("portfolio telemetry");
        assert_eq!(p.total_routes(), m.pool.requests);
        assert!(p.cache.lookups > 0);
        assert!(p.cache.exact_hits > 0, "repeated documents must hit the cache");
        assert!(m.report().contains("portfolio"));
        svc.shutdown();
    }

    #[test]
    fn sched_disabled_falls_back_to_local_workers() {
        let mut settings = test_settings();
        settings.sched.enabled = false;
        let svc = Service::start(&settings).unwrap();
        assert!(!svc.is_pooled());
        let set = benchmark_set("bench_10").unwrap();
        let t = svc.submit(set.documents[0].clone()).unwrap();
        assert_eq!(t.wait().unwrap().selected.len(), 3);
        assert_eq!(svc.metrics().pool.devices, 0);
        svc.shutdown();
    }

    #[test]
    fn non_ising_solvers_run_local_even_with_sched_enabled() {
        let mut settings = test_settings();
        settings.pipeline.solver = "exact".into();
        let svc = Service::start(&settings).unwrap();
        assert!(!svc.is_pooled());
        let set = benchmark_set("bench_10").unwrap();
        let t = svc.submit(set.documents[1].clone()).unwrap();
        assert_eq!(t.wait().unwrap().selected.len(), 3);
        svc.shutdown();
    }

    #[test]
    fn too_short_documents_fail_cleanly() {
        let svc = Service::start(&test_settings()).unwrap();
        let doc = Document::from_text("tiny", "Too short.");
        let t = svc.submit(doc).unwrap();
        assert!(t.wait().is_err());
        let m = svc.metrics();
        assert_eq!(m.failed, 1);
        svc.shutdown();
    }
}
