//! Edge summarization service — the deployment shape the paper's
//! conclusion targets ("real-time, low-power summarization engines in
//! edge devices").
//!
//! Architecture (threads + channels; no tokio in the offline vendor set):
//!
//!   clients ──> Router (bounded queue, backpressure) ──> worker pool
//!                                                        each worker owns
//!                                                        an EsPipeline +
//!                                                        COBI device
//!
//! The router batches queued requests up to `max_batch` per dispatch (one
//! channel send per batch, amortizing wakeups), rejects when the queue is
//! full, and aggregates latency/throughput metrics.
//!
//! Ising solves route through the shared `sched::DevicePool` by default
//! (pool-capable solvers: cobi/tabu/sa, or the adaptive "portfolio"
//! backend when `[portfolio] enabled = true`), so subproblems from ALL
//! in-flight documents coalesce into batched device dispatches; workers
//! fall back to private solvers for brute/exact/random or when
//! `[sched] enabled = false`. Documents decompose per
//! `[decompose] strategy` (window / tree / stream), and
//! [`Service::open_stream`] serves incremental `SUMMARIZE_STREAM`
//! sessions with per-chunk summary revisions. See DESIGN.md §Sched and
//! §Portfolio, and docs/ARCHITECTURE.md for the request walkthrough.

pub mod metrics;
pub mod overload;
pub mod tcp;
pub mod worker;

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::config::Settings;
use crate::corpus::Document;
use crate::obs::ObsShared;
use crate::pipeline::Summary;
use crate::resilience::ResilienceShared;
use crate::runtime::ArtifactRuntime;
use crate::sched::pool::PoolSolver;
use crate::sched::{self, DevicePool, PoolClient, StreamRoute, StreamSummarizer};

pub use metrics::{OverloadMetrics, ServiceMetrics, StrategyMetrics, WorkloadMetrics};
pub use overload::{AdmissionController, Deadline, DeadlineExceeded, Shed, Tier};
use worker::{spawn_workers, Job, SolveRoute};

/// Rejected-due-to-backpressure error marker.
#[derive(Debug, thiserror::Error)]
#[error("service queue full (backpressure): retry later")]
pub struct Overloaded;

/// Per-request submission options: the admission tier (batch sheds
/// first under pressure — DESIGN.md decision #20) and an optional
/// end-to-end deadline. `Default` is an interactive request with the
/// configured `[service] default_deadline_ms` (none when 0).
#[derive(Debug, Clone, Copy, Default)]
pub struct SubmitOptions {
    /// Admission tier; batch is shed before interactive under overload.
    pub tier: Tier,
    /// Explicit deadline; `None` applies the configured default.
    pub deadline: Option<Deadline>,
    /// Registered workload name (`crate::workload::WORKLOADS`). The empty
    /// default means ES — the legacy text path, byte-identical to every
    /// pre-platform release. Non-ES requests carry their body in the
    /// document's sentences (see `crate::workload::problem_from_request`).
    pub workload: &'static str,
}

/// Outcome of a graceful drain (see [`Service::drain`]).
#[derive(Debug, Clone, Copy)]
pub struct DrainStats {
    /// In-flight requests that finished inside the drain window.
    pub clean: usize,
    /// Requests still in flight when the window closed.
    pub aborted: usize,
    /// Time spent waiting for the queue to empty.
    pub waited: Duration,
}

/// Client-side handle for one submitted request.
pub struct Ticket {
    /// Request id (unique per service).
    pub id: u64,
    rx: Receiver<Result<Summary>>,
    submitted: Instant,
}

impl Ticket {
    /// Block until the summary is ready.
    pub fn wait(self) -> Result<Summary> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => bail!("service dropped the request (shutdown?)"),
        }
    }

    /// Time since submission.
    pub fn elapsed(&self) -> std::time::Duration {
        self.submitted.elapsed()
    }
}

/// Where a [`ServiceStream`]'s solves run (owned variants of
/// [`StreamRoute`]).
enum StreamOwner {
    Pooled(PoolClient),
    Local(Box<dyn PoolSolver>),
}

/// One open incremental summarization session (see
/// [`Service::open_stream`]). Chunks in, summary revisions out; close it
/// with [`finish`](ServiceStream::finish). Counter contract: opening a
/// session counts one `submitted`; a successful `finish` counts one
/// `completed` (+ one stream-strategy summary); a failed `finish` — or
/// abandoning the session without finishing (client disconnect, ingest
/// error) — counts one `failed`, so `submitted = completed + failed`
/// holds across batch and stream traffic alike.
pub struct ServiceStream {
    inner: StreamSummarizer,
    route: StreamOwner,
    metrics: Arc<Mutex<ServiceMetrics>>,
    /// True once `finish` settled the session's completed/failed counter.
    settled: bool,
}

impl ServiceStream {
    /// Ingest one chunk of raw text (sentence-split internally; chunk
    /// boundaries must fall between sentences). Returns the number of
    /// sentences ingested.
    pub fn push_text(&mut self, text: &str) -> Result<usize> {
        let inner = &mut self.inner;
        let n = match &mut self.route {
            StreamOwner::Pooled(client) => {
                inner.push_text(text, &mut StreamRoute::Pooled(client))
            }
            StreamOwner::Local(solver) => {
                inner.push_text(text, &mut StreamRoute::Inline(solver.as_mut()))
            }
        }?;
        self.metrics.lock().unwrap().strategies.stream_chunks += 1;
        Ok(n)
    }

    /// True once enough sentences arrived to fill a summary.
    pub fn can_summarize(&self) -> bool {
        self.inner.can_summarize()
    }

    /// Serve a summary revision over the current frontier.
    pub fn revision(&mut self) -> Result<Summary> {
        let inner = &mut self.inner;
        let summary = match &mut self.route {
            StreamOwner::Pooled(client) => inner.revision(&mut StreamRoute::Pooled(client)),
            StreamOwner::Local(solver) => {
                inner.revision(&mut StreamRoute::Inline(solver.as_mut()))
            }
        }?;
        self.metrics.lock().unwrap().strategies.stream_revisions += 1;
        Ok(summary)
    }

    /// Close the session with a final revision, settling its
    /// completed/failed counter (see the type docs).
    pub fn finish(mut self) -> Result<Summary> {
        self.settled = true;
        let result = self.revision();
        let mut m = self.metrics.lock().unwrap();
        match &result {
            Ok(_) => {
                m.completed += 1;
                m.strategies.record(crate::decompose::Strategy::Streaming);
            }
            Err(_) => m.failed += 1,
        }
        drop(m);
        result
    }

    /// Total sentences ingested so far.
    pub fn arrived(&self) -> usize {
        self.inner.arrived()
    }
}

impl Drop for ServiceStream {
    fn drop(&mut self) {
        // abandoned mid-session (ingest error, client disconnect):
        // settle as failed so submitted = completed + failed holds
        if !self.settled {
            self.metrics.lock().unwrap().failed += 1;
        }
    }
}

/// The running service.
pub struct Service {
    tx: SyncSender<Job>,
    metrics: Arc<Mutex<ServiceMetrics>>,
    inflight: Arc<AtomicUsize>,
    next_id: AtomicUsize,
    stop: Arc<AtomicBool>,
    workers: Vec<std::thread::JoinHandle<()>>,
    queue_depth: usize,
    /// Shared solve pool (None when running worker-private solvers).
    pool: Option<DevicePool>,
    /// Service-owned resilience counter block for the LOCAL route (the
    /// pooled route's block lives in the pool); present when the
    /// resilience layer or the fault model is enabled without a pool,
    /// so `::STATS::` reports the counters either way.
    resilience: Option<ResilienceShared>,
    /// Observability: span collector + energy ledger + dispatch counters
    /// shared with the pool, workers and stream sessions.
    obs: ObsShared,
    /// Admission controller: load-shedding by tier when the estimated
    /// queue wait exceeds `[service] shed_watermark_ms` (inert at 0).
    admission: Arc<AdmissionController>,
    /// Set once a drain begins; submissions are rejected from then on.
    draining: Arc<AtomicBool>,
    /// Retained for late construction of stream-session solvers.
    settings: Settings,
}

impl Service {
    /// Start the worker pool per `settings.service` (+ the shared device
    /// pool per `settings.sched` when enabled and solver-compatible).
    pub fn start(settings: &Settings) -> Result<Self> {
        Self::start_with(settings, None)
    }

    /// As [`Service::start`], with an artifact runtime for the COBI-HLO
    /// pool backend.
    pub fn start_with(settings: &Settings, rt: Option<&ArtifactRuntime>) -> Result<Self> {
        let metrics = Arc::new(Mutex::new(ServiceMetrics::default()));
        let inflight = Arc::new(AtomicUsize::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = sync_channel::<Job>(settings.service.queue_depth);
        let obs = ObsShared::from_settings(settings);

        let pool = if sched::service_pooled(settings) {
            Some(DevicePool::start_obs(settings, rt, Some(&obs))?)
        } else {
            None
        };
        // without a pool, the service hosts the fleet resilience block
        // itself so local-route worker/stream counters still aggregate
        let resilience = (pool.is_none()
            && (settings.resilience.enabled || settings.resilience.fault.enabled))
            .then(ResilienceShared::new);
        let route = match &pool {
            Some(p) => SolveRoute::Pooled(p.handle()),
            None => SolveRoute::Local,
        };

        // the retry-after jitter stream is seeded from the pipeline seed,
        // so shed hints are reproducible run-to-run like everything else
        let admission = Arc::new(AdmissionController::from_config(
            &settings.service,
            settings.pipeline.seed,
        ));
        let workers = spawn_workers(
            settings,
            rx,
            metrics.clone(),
            inflight.clone(),
            stop.clone(),
            route,
            rt,
            resilience.as_ref(),
            &obs,
            admission.clone(),
        )?;
        Ok(Self {
            tx,
            metrics,
            inflight,
            next_id: AtomicUsize::new(1),
            stop,
            workers,
            queue_depth: settings.service.queue_depth,
            pool,
            resilience,
            obs,
            admission,
            draining: Arc::new(AtomicBool::new(false)),
            settings: settings.clone(),
        })
    }

    /// Open an incremental summarization session (the service face of
    /// `SUMMARIZE_STREAM`): feed text chunks as they arrive, get a
    /// summary revision after any chunk, close with a final revision.
    ///
    /// Sessions run on the CALLER's thread — the worker queue is for
    /// whole-document jobs; a stream's heavy lifting (the Ising solves)
    /// still lands on the shared device pool when one is running, so
    /// concurrent sessions and batch traffic coalesce on the same
    /// devices. Without a pool the session owns a private pool-capable
    /// solver (cobi/tabu/sa — brute/exact/random cannot stream).
    ///
    /// Determinism: the session seed is `doc_seed(cfg.seed, id)`, and
    /// every compression/revision node seeds from its arrival position,
    /// so two sessions with the same id receiving the same sentences —
    /// in ANY chunking, against ANY pool shape — revise identically.
    pub fn open_stream(&self, id: &str) -> Result<ServiceStream> {
        let seed = sched::doc_seed(self.settings.pipeline.seed, id);
        let mut cfg = self.settings.pipeline.clone();
        cfg.seed = seed;
        let route = match &self.pool {
            Some(pool) => StreamOwner::Pooled(pool.client(seed)),
            None => {
                let backend = sched::resolved_backend(&self.settings).to_string();
                let solver = sched::pool::build_solver(
                    &backend,
                    &self.settings,
                    seed,
                    None,
                    None,
                    self.resilience.as_ref(),
                    Some((&self.obs, crate::obs::Subsystem::Stream)),
                    None,
                )
                .map_err(|e| {
                    anyhow::anyhow!(
                        "streaming needs a pool-capable solver \
                         (cobi/tabu/sa/portfolio): {e}"
                    )
                })?;
                StreamOwner::Local(solver)
            }
        };
        let inner = StreamSummarizer::new(id, &cfg)?;
        {
            // a session is one logical request: count it submitted here,
            // settled (completed/failed) by finish or drop
            let mut m = self.metrics.lock().unwrap();
            m.submitted += 1;
            m.strategies.stream_sessions += 1;
        }
        Ok(ServiceStream {
            inner,
            route,
            metrics: self.metrics.clone(),
            settled: false,
        })
    }

    /// Submit a document with default options (interactive tier, the
    /// configured default deadline); non-blocking. Errors with
    /// [`Overloaded`] when the queue is full (backpressure) instead of
    /// buffering unboundedly.
    pub fn submit(&self, doc: Document) -> Result<Ticket> {
        self.submit_with(doc, SubmitOptions::default())
    }

    /// Submit a document with an explicit tier and deadline; non-blocking.
    ///
    /// Rejection order under pressure (DESIGN.md decision #20): a
    /// draining service rejects everything; the admission controller
    /// sheds batch traffic at the configured watermark and interactive
    /// traffic only at 4x the watermark (typed [`Shed`] carrying a
    /// seeded retry-after hint); a full queue is the hard cap — it sheds
    /// whatever arrives, reported as [`Shed`] when admission control is
    /// on and [`Overloaded`] otherwise.
    pub fn submit_with(&self, doc: Document, opts: SubmitOptions) -> Result<Ticket> {
        if self.draining.load(Ordering::SeqCst) {
            self.metrics.lock().unwrap().rejected += 1;
            bail!("service draining: not accepting new requests");
        }
        let workers = self.settings.service.workers.max(1);
        if let Err(shed) = self.admission.admit(opts.tier, self.inflight(), workers) {
            self.count_shed(opts.tier);
            return Err(shed.into());
        }
        let deadline = opts.deadline.or_else(|| {
            let ms = self.settings.service.default_deadline_ms;
            (ms > 0).then(|| Deadline::from_ms(ms))
        });
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) as u64;
        let (otx, orx) = sync_channel(1);
        let job = Job {
            id,
            doc,
            respond: otx,
            enqueued: Instant::now(),
            tier: opts.tier,
            deadline,
            workload: opts.workload,
        };
        match self.tx.try_send(job) {
            Ok(()) => {
                self.inflight.fetch_add(1, Ordering::Relaxed);
                self.metrics.lock().unwrap().submitted += 1;
                Ok(Ticket {
                    id,
                    rx: orx,
                    submitted: Instant::now(),
                })
            }
            Err(TrySendError::Full(_)) => {
                if self.admission.enabled() {
                    self.count_shed(opts.tier);
                    Err(self.admission.shed(opts.tier).into())
                } else {
                    self.metrics.lock().unwrap().rejected += 1;
                    Err(Overloaded.into())
                }
            }
            Err(TrySendError::Disconnected(_)) => bail!("service stopped"),
        }
    }

    /// Count one shed rejection against the tier's overload counter.
    fn count_shed(&self, tier: Tier) {
        let mut m = self.metrics.lock().unwrap();
        m.rejected += 1;
        match tier {
            Tier::Batch => m.overload.shed_batch += 1,
            Tier::Interactive => m.overload.shed_interactive += 1,
        }
    }

    /// Requests currently queued or executing.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Configured queue bound.
    pub fn queue_depth(&self) -> usize {
        self.queue_depth
    }

    /// True once a drain has begun — submissions are rejected.
    pub fn draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Configured per-connection idle/read timeout (`None` when 0).
    pub fn idle_timeout(&self) -> Option<Duration> {
        let ms = self.settings.service.idle_timeout_ms;
        (ms > 0).then(|| Duration::from_millis(ms))
    }

    /// Configured inbound document size cap (`None` when 0).
    pub fn max_doc_bytes(&self) -> Option<usize> {
        let b = self.settings.service.max_doc_bytes;
        (b > 0).then_some(b)
    }

    /// Graceful drain: stop admitting new requests, then wait up to
    /// `limit` for the in-flight ones to finish. Every request accepted
    /// before the drain either completes normally or (past the window)
    /// is failed fast by the stopping workers — its reply channel is
    /// answered either way, so no client hangs on a lost response.
    pub fn drain(&self, limit: Duration) -> DrainStats {
        let start = Instant::now();
        let first = !self.draining.swap(true, Ordering::SeqCst);
        if first {
            self.metrics.lock().unwrap().overload.drains += 1;
        }
        let initial = self.inflight();
        while self.inflight() > 0 && start.elapsed() < limit {
            std::thread::sleep(Duration::from_millis(2));
        }
        let aborted = self.inflight();
        if aborted > 0 {
            self.metrics.lock().unwrap().overload.drain_aborted += aborted as u64;
        }
        DrainStats {
            clean: initial.saturating_sub(aborted),
            aborted,
            waited: start.elapsed(),
        }
    }

    /// Metrics snapshot, including the device-pool counters (and, when
    /// enabled, the solver portfolio's route/cache telemetry and the
    /// resilience layer's replication/vote/retry/fault counters).
    pub fn metrics(&self) -> ServiceMetrics {
        let mut m = self.metrics.lock().unwrap().clone();
        if let Some(pool) = &self.pool {
            m.pool = pool.metrics();
            m.portfolio = pool.portfolio_metrics();
            m.resilience = pool.resilience_metrics();
            m.breaker = pool.breaker_metrics();
        } else if let Some(r) = &self.resilience {
            m.resilience = Some(r.snapshot());
        }
        m.obs = Some(self.obs.snapshot());
        m
    }

    /// The service's observability handle (trace collector + energy
    /// ledger) — the `serve` loop drains JSONL exports through it.
    pub fn obs(&self) -> &ObsShared {
        &self.obs
    }

    /// Re-execute flight-recorder ring entry `id` through the current
    /// configuration and byte-diff it against the recording (the
    /// `::REPLAY <id>::` admin frame). Errors when recording is off or
    /// the id is unknown/overwritten.
    pub fn replay(&self, id: u64) -> Result<crate::obs::ReplayReport> {
        let recorder = self.obs.recorder();
        if !recorder.enabled() {
            bail!("flight recorder disabled ([obs] record_enabled / --record-out)");
        }
        let rec = recorder.get(id).ok_or_else(|| {
            anyhow::anyhow!(
                "no record {id} in the ring ({} buffered, {} overwritten)",
                recorder.buffered(),
                recorder.overwritten()
            )
        })?;
        crate::obs::replay_record(&rec, &self.settings)
    }

    /// True when Ising solves route through the shared device pool.
    pub fn is_pooled(&self) -> bool {
        self.pool.is_some()
    }

    /// Graceful shutdown: drain in-flight work under the configured
    /// `[service] drain_deadline_ms` window, then stop the workers and
    /// the pool. Requests that outlive the window are failed fast by the
    /// stopping workers — answered, not dropped.
    pub fn shutdown(self) {
        let limit = Duration::from_millis(self.settings.service.drain_deadline_ms.max(1));
        let _ = self.drain(limit);
        self.stop.store(true, Ordering::SeqCst);
        drop(self.tx); // closes the queue; workers exit after draining
        for w in self.workers {
            let _ = w.join();
        }
        // workers dropped their PoolHandles on exit; the pool's own
        // sender is the last one, so device threads drain and join here
        if let Some(pool) = self.pool {
            pool.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::benchmark_set;

    fn test_settings() -> Settings {
        let mut s = Settings::default();
        s.service.workers = 2;
        s.service.queue_depth = 8;
        s.pipeline.solver = "tabu".into();
        s.pipeline.iterations = 2;
        s.pipeline.summary_len = 3;
        s
    }

    #[test]
    fn serves_requests_end_to_end() {
        let settings = test_settings();
        let svc = Service::start(&settings).unwrap();
        let set = benchmark_set("bench_10").unwrap();
        let tickets: Vec<Ticket> = set.documents[..4]
            .iter()
            .map(|d| svc.submit(d.clone()).unwrap())
            .collect();
        for t in tickets {
            let s = t.wait().unwrap();
            assert_eq!(s.selected.len(), 3);
        }
        let m = svc.metrics();
        assert_eq!(m.submitted, 4);
        assert_eq!(m.completed, 4);
        assert_eq!(m.failed, 0);
        svc.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let mut settings = test_settings();
        settings.service.workers = 1;
        settings.service.queue_depth = 1;
        settings.pipeline.iterations = 10; // slow enough to pile up
        let svc = Service::start(&settings).unwrap();
        let set = benchmark_set("cnn_dm_20").unwrap();
        let mut accepted = 0usize;
        let mut rejected = 0usize;
        let mut tickets = Vec::new();
        for d in &set.documents {
            match svc.submit(d.clone()) {
                Ok(t) => {
                    accepted += 1;
                    tickets.push(t);
                }
                Err(_) => rejected += 1,
            }
        }
        assert!(rejected > 0, "no backpressure observed");
        for t in tickets {
            let _ = t.wait();
        }
        assert_eq!(svc.metrics().rejected as usize, rejected);
        let _ = accepted;
        svc.shutdown();
    }

    #[test]
    fn shutdown_joins_workers() {
        let svc = Service::start(&test_settings()).unwrap();
        svc.shutdown(); // must not hang
    }

    #[test]
    fn pooled_route_is_default_and_reports_occupancy() {
        let mut settings = test_settings();
        settings.service.workers = 4;
        settings.sched.devices = 2;
        settings.sched.linger_us = 2_000;
        let svc = Service::start(&settings).unwrap();
        assert!(svc.is_pooled());
        let set = benchmark_set("bench_10").unwrap();
        let tickets: Vec<Ticket> = set
            .documents
            .iter()
            .map(|d| svc.submit(d.clone()).unwrap())
            .collect();
        for t in tickets {
            assert_eq!(t.wait().unwrap().selected.len(), 3);
        }
        let m = svc.metrics();
        assert_eq!(m.completed, 20);
        // bench_10 docs are single-stage: one pool request per document,
        // `iterations` instances per request
        assert_eq!(m.pool.requests, 20);
        assert_eq!(
            m.pool.instances,
            20 * settings.pipeline.iterations as u64
        );
        assert!(m.pool.dispatches >= 1);
        // occupancy > 1 here certifies instance-level amortization (each
        // request carries `iterations` instances); cross-document request
        // fusion is timing-dependent under test load, so coalescing() > 1
        // is pinned by the dedicated pool test instead
        // (sched::pool::tests::concurrent_clients_coalesce)
        assert!(
            m.pool.batch_occupancy() > 1.0,
            "occupancy {} not > 1",
            m.pool.batch_occupancy()
        );
        assert_eq!(m.pool.queue_wait.count(), 20);
        assert!(m.queue_hist.count() >= 20);
        assert!(m.report().contains("occupancy"));
        svc.shutdown();
    }

    #[test]
    fn portfolio_route_surfaces_telemetry_in_service_metrics() {
        let mut settings = test_settings();
        settings.portfolio.enabled = true; // static cobi + warm cache
        let svc = Service::start(&settings).unwrap();
        assert!(svc.is_pooled());
        let set = benchmark_set("bench_10").unwrap();
        // first wave populates the fleet-wide cache...
        let tickets: Vec<Ticket> = set
            .documents
            .iter()
            .map(|d| svc.submit(d.clone()).unwrap())
            .collect();
        for t in tickets {
            assert_eq!(t.wait().unwrap().selected.len(), 3);
        }
        // ...an identical second wave (same doc ids => same doc seeds =>
        // identical quantized instances) must exact-hit it
        let tickets: Vec<Ticket> = set
            .documents
            .iter()
            .map(|d| svc.submit(d.clone()).unwrap())
            .collect();
        for t in tickets {
            assert_eq!(t.wait().unwrap().selected.len(), 3);
        }
        let m = svc.metrics();
        assert_eq!(m.completed, 40);
        let p = m.portfolio.expect("portfolio telemetry");
        assert_eq!(p.total_routes(), m.pool.requests);
        assert!(p.cache.lookups > 0);
        assert!(p.cache.exact_hits > 0, "repeated documents must hit the cache");
        assert!(m.report().contains("portfolio"));
        svc.shutdown();
    }

    #[test]
    fn stream_sessions_summarize_incrementally() {
        let settings = test_settings();
        let svc = Service::start(&settings).unwrap();
        assert!(svc.is_pooled());
        let set = benchmark_set("cnn_dm_20").unwrap();
        let doc = &set.documents[0];
        let mut session = svc.open_stream(&doc.id).unwrap();
        let n = session.push_text(&doc.text()).unwrap();
        assert_eq!(n, 20);
        assert!(session.can_summarize());
        let rev = session.revision().unwrap();
        assert_eq!(rev.selected.len(), 3);
        // finish at the same arrival count replays the identical revision
        let fin = session.finish().unwrap();
        assert_eq!(fin.selected, rev.selected);
        assert_eq!(fin.sentences, rev.sentences);
        let m = svc.metrics();
        assert_eq!(m.strategies.stream_sessions, 1);
        assert_eq!(m.strategies.stream_chunks, 1);
        assert_eq!(m.strategies.stream_revisions, 2);
        assert_eq!(m.strategies.stream, 1);
        // sessions keep the counter identity: one submitted, one
        // completed, nothing failed
        assert_eq!(m.submitted, 1);
        assert_eq!(m.completed, 1);
        assert_eq!(m.failed, 0);
        assert!(m.report().contains("strategy"), "{}", m.report());

        // an abandoned session settles as failed on drop
        let dangling = svc.open_stream("abandoned").unwrap();
        drop(dangling);
        let m = svc.metrics();
        assert_eq!(m.submitted, 2);
        assert_eq!(m.failed, 1);
        assert_eq!(m.completed, 1);
        svc.shutdown();
    }

    #[test]
    fn stream_sessions_run_locally_when_the_pool_is_off() {
        let mut settings = test_settings();
        settings.sched.enabled = false;
        let svc = Service::start(&settings).unwrap();
        assert!(!svc.is_pooled());
        let set = benchmark_set("cnn_dm_20").unwrap();
        let mut session = svc.open_stream("local-stream").unwrap();
        session.push_text(&set.documents[1].text()).unwrap();
        let fin = session.finish().unwrap();
        assert_eq!(fin.selected.len(), 3);
        svc.shutdown();
    }

    #[test]
    fn stream_sessions_reject_non_pool_capable_local_solvers() {
        let mut settings = test_settings();
        settings.pipeline.solver = "exact".into(); // forces the local route
        let svc = Service::start(&settings).unwrap();
        assert!(!svc.is_pooled());
        assert!(svc.open_stream("nope").is_err());
        svc.shutdown();
    }

    #[test]
    fn strategy_counters_track_completed_submits() {
        let mut settings = test_settings();
        settings.pipeline.strategy = crate::decompose::Strategy::Tree;
        let svc = Service::start(&settings).unwrap();
        let set = benchmark_set("bench_10").unwrap();
        let tickets: Vec<Ticket> = set.documents[..3]
            .iter()
            .map(|d| svc.submit(d.clone()).unwrap())
            .collect();
        for t in tickets {
            assert_eq!(t.wait().unwrap().selected.len(), 3);
        }
        let m = svc.metrics();
        assert_eq!(m.strategies.tree, 3);
        assert_eq!(m.strategies.window, 0);
        svc.shutdown();
    }

    #[test]
    fn sched_disabled_falls_back_to_local_workers() {
        let mut settings = test_settings();
        settings.sched.enabled = false;
        let svc = Service::start(&settings).unwrap();
        assert!(!svc.is_pooled());
        let set = benchmark_set("bench_10").unwrap();
        let t = svc.submit(set.documents[0].clone()).unwrap();
        assert_eq!(t.wait().unwrap().selected.len(), 3);
        assert_eq!(svc.metrics().pool.devices, 0);
        svc.shutdown();
    }

    #[test]
    fn non_ising_solvers_run_local_even_with_sched_enabled() {
        let mut settings = test_settings();
        settings.pipeline.solver = "exact".into();
        let svc = Service::start(&settings).unwrap();
        assert!(!svc.is_pooled());
        let set = benchmark_set("bench_10").unwrap();
        let t = svc.submit(set.documents[1].clone()).unwrap();
        assert_eq!(t.wait().unwrap().selected.len(), 3);
        svc.shutdown();
    }

    #[test]
    fn obs_traces_and_ledger_surface_in_service_metrics() {
        let mut settings = test_settings();
        settings.obs.enabled = true;
        let svc = Service::start(&settings).unwrap();
        assert!(svc.is_pooled());
        let set = benchmark_set("bench_10").unwrap();
        let tickets: Vec<Ticket> = set.documents[..4]
            .iter()
            .map(|d| svc.submit(d.clone()).unwrap())
            .collect();
        for t in tickets {
            t.wait().unwrap();
        }
        let m = svc.metrics();
        let o = m.obs.expect("obs snapshot");
        assert!(o.tracing_enabled);
        assert_eq!(o.recorded, 4, "one span tree per served request");
        assert!(!o.exemplars.is_empty(), "slowest-request exemplars kept");
        // the tabu pool route charges every fresh instance to the ledger
        assert!(o.total_joules() > 0.0, "ledger uncharged");
        assert!(
            o.ledger
                .iter()
                .all(|r| r.backend == "tabu" && r.subsystem == "pool"),
            "{:?}",
            o.ledger
        );
        let charged: u64 = o.ledger.iter().map(|r| r.cell.solves).sum();
        assert_eq!(charged, 4 * settings.pipeline.iterations as u64);
        assert!(o.dispatches >= 1, "device dispatches counted");
        assert_eq!(o.dispatch_instances, charged);
        // buffered trees are drainable (the serve loop's JSONL export)
        let drained = svc.obs().traces().drain();
        assert_eq!(drained.len() as u64 + o.dropped, 4);
        assert!(drained
            .iter()
            .all(|s| s.stage == "request" && !s.children.is_empty()));
        assert!(m.report().contains("obs:"), "{}", m.report());
        svc.shutdown();
    }

    #[test]
    fn served_requests_are_recorded_and_replayable_in_process() {
        // tier-1 (ungated) variant of the CI replay smoke: serve a
        // burst with the flight recorder on, then replay every ring
        // entry through Service::replay — all byte-identical
        let mut settings = test_settings();
        settings.obs.record_enabled = true;
        let svc = Service::start(&settings).unwrap();
        let set = benchmark_set("bench_10").unwrap();
        let tickets: Vec<Ticket> = set.documents[..4]
            .iter()
            .map(|d| svc.submit(d.clone()).unwrap())
            .collect();
        for t in tickets {
            t.wait().unwrap();
        }
        let o = svc.metrics().obs.expect("obs snapshot");
        assert!(o.recorder_enabled);
        assert_eq!(o.recorder_recorded, 4);
        assert_eq!(o.recorder_buffered, 4);
        assert_eq!(o.recorder_overwritten, 0);
        for rec in svc.obs().recorder().snapshot() {
            assert!(!rec.nodes.is_empty(), "pooled ES requests tap nodes");
            let report = svc.replay(rec.id).unwrap();
            assert!(report.identical, "{}", report.verdict_line());
            assert!(report.config_diff.is_empty());
        }
        assert!(svc.replay(999).is_err(), "unknown id errors");
        svc.shutdown();
    }

    #[test]
    fn recorder_off_by_default_and_replay_refuses() {
        let svc = Service::start(&test_settings()).unwrap();
        let set = benchmark_set("bench_10").unwrap();
        let t = svc.submit(set.documents[0].clone()).unwrap();
        t.wait().unwrap();
        let o = svc.metrics().obs.expect("obs snapshot");
        assert!(!o.recorder_enabled);
        assert_eq!(o.recorder_recorded, 0);
        let err = svc.replay(1).unwrap_err();
        assert!(err.to_string().contains("disabled"), "{err}");
        svc.shutdown();
    }

    #[test]
    fn too_short_documents_fail_cleanly() {
        let svc = Service::start(&test_settings()).unwrap();
        let doc = Document::from_text("tiny", "Too short.");
        let t = svc.submit(doc).unwrap();
        assert!(t.wait().is_err());
        let m = svc.metrics();
        assert_eq!(m.failed, 1);
        svc.shutdown();
    }

    #[test]
    fn admission_sheds_batch_before_interactive() {
        let mut settings = test_settings();
        settings.service.workers = 1;
        settings.service.shed_watermark_ms = 150;
        let svc = Service::start(&settings).unwrap();
        let set = benchmark_set("bench_10").unwrap();
        // warm the wait estimator and pin a synthetic backlog so the
        // admit decision is deterministic (no races against real solves):
        // estimated wait = 5 inflight x 100ms / 1 worker = 500ms, which
        // is past the 150ms batch watermark but inside the 600ms
        // interactive limit (4x)
        svc.admission.observe_solve(Duration::from_millis(100));
        svc.inflight.fetch_add(4, Ordering::Relaxed);
        let batch = SubmitOptions {
            tier: Tier::Batch,
            ..Default::default()
        };
        let err = svc.submit_with(set.documents[0].clone(), batch).unwrap_err();
        let shed = err.downcast_ref::<Shed>().expect("typed Shed error");
        assert_eq!(shed.tier, Tier::Batch);
        assert!(
            shed.retry_after_ms >= 150 && shed.retry_after_ms < 300,
            "retry hint {} outside [watermark, 2*watermark)",
            shed.retry_after_ms
        );
        // the same instant, an interactive request still gets in
        let t = svc
            .submit_with(set.documents[1].clone(), SubmitOptions::default())
            .unwrap();
        assert_eq!(t.wait().unwrap().selected.len(), 3);
        let m = svc.metrics();
        assert_eq!(m.rejected, 1);
        assert_eq!(m.overload.shed_batch, 1);
        assert_eq!(m.overload.shed_interactive, 0);
        assert!(m.report().contains("shed_batch=1"), "{}", m.report());
        svc.inflight.fetch_sub(4, Ordering::Relaxed);
        svc.shutdown();
    }

    #[test]
    fn expired_deadlines_get_typed_replies_and_counters() {
        let svc = Service::start(&test_settings()).unwrap();
        let set = benchmark_set("bench_10").unwrap();
        let opts = SubmitOptions {
            deadline: Some(Deadline::from_ms(0)),
            ..Default::default()
        };
        let t = svc.submit_with(set.documents[0].clone(), opts).unwrap();
        let err = t.wait().unwrap_err();
        assert!(
            err.downcast_ref::<DeadlineExceeded>().is_some(),
            "want DeadlineExceeded, got: {err}"
        );
        let m = svc.metrics();
        assert_eq!(m.overload.deadline_exceeded, 1);
        assert_eq!(m.failed, 1);
        svc.shutdown();
    }

    #[test]
    fn default_deadline_from_config_is_generous_enough_to_serve() {
        let mut settings = test_settings();
        settings.service.default_deadline_ms = 60_000;
        let svc = Service::start(&settings).unwrap();
        let set = benchmark_set("bench_10").unwrap();
        let t = svc.submit(set.documents[0].clone()).unwrap();
        assert_eq!(t.wait().unwrap().selected.len(), 3);
        assert_eq!(svc.metrics().overload.deadline_exceeded, 0);
        svc.shutdown();
    }

    #[test]
    fn drain_finishes_inflight_and_rejects_new_work() {
        let svc = Service::start(&test_settings()).unwrap();
        let set = benchmark_set("bench_10").unwrap();
        let tickets: Vec<Ticket> = set.documents[..4]
            .iter()
            .map(|d| svc.submit(d.clone()).unwrap())
            .collect();
        let stats = svc.drain(Duration::from_secs(30));
        assert_eq!(stats.aborted, 0, "in-flight work must finish in-window");
        assert!(svc.draining());
        let err = svc.submit(set.documents[5].clone()).unwrap_err();
        assert!(err.to_string().contains("draining"), "{err}");
        // zero lost responses: every accepted request answers
        for t in tickets {
            assert_eq!(t.wait().unwrap().selected.len(), 3);
        }
        let m = svc.metrics();
        assert_eq!(m.completed, 4);
        assert_eq!(m.overload.drains, 1);
        assert_eq!(m.overload.drain_aborted, 0);
        svc.shutdown();
    }
}
