//! TCP front-end: a line-oriented protocol over the summarization
//! service, making `cobi-es serve --port N` a real network endpoint for
//! edge deployments.
//!
//! Protocol (one request per connection, newline-framed):
//!   client sends the document text terminated by a line containing
//!   exactly `::EOF::`;
//!   server replies `OK <m>` followed by the m summary sentences (one per
//!   line) and closes, or `ERR <message>`.
//!
//! A first line of exactly `::STATS::` instead requests the service
//! metrics report (counts, latency percentiles and — when the shared
//! device pool is running — batch occupancy / coalescing / utilization):
//! the server replies `OK 1` followed by one report line.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::corpus::Document;

use super::Service;

pub const EOF_MARKER: &str = "::EOF::";
pub const STATS_MARKER: &str = "::STATS::";

/// A running TCP endpoint over a Service.
pub struct TcpServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl TcpServer {
    /// Bind and serve in background threads. Port 0 picks a free port.
    pub fn start(service: Arc<Service>, port: u16) -> Result<Self> {
        let listener =
            TcpListener::bind(("127.0.0.1", port)).context("binding tcp listener")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let accept_thread = std::thread::Builder::new()
            .name("cobi-tcp-accept".into())
            .spawn(move || {
                let mut conn_id = 0u64;
                while !stop2.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            conn_id += 1;
                            let svc = service.clone();
                            let id = conn_id;
                            // one thread per connection: edge workloads are
                            // low-concurrency; the Service queue is the
                            // real admission control
                            let _ = std::thread::Builder::new()
                                .name(format!("cobi-tcp-conn-{id}"))
                                .spawn(move || {
                                    let _ = handle_connection(&svc, stream, id);
                                });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Self {
            addr,
            stop,
            accept_thread: Some(accept_thread),
        })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_connection(service: &Service, stream: TcpStream, id: u64) -> Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_secs(30)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut text = String::new();
    let mut line = String::new();
    let mut first = true;
    loop {
        line.clear();
        let n = reader.read_line(&mut line)?;
        if first && line.trim_end() == STATS_MARKER {
            let mut out = stream;
            writeln!(out, "OK 1")?;
            writeln!(out, "{}", service.metrics().report())?;
            return Ok(());
        }
        first = false;
        if n == 0 || line.trim_end() == EOF_MARKER {
            break;
        }
        text.push_str(&line);
    }
    let mut out = stream;
    let doc = Document::from_text(&format!("tcp-{id}"), &text);
    let reply = service
        .submit(doc)
        .and_then(|ticket| ticket.wait());
    match reply {
        Ok(summary) => {
            writeln!(out, "OK {}", summary.sentences.len())?;
            for s in &summary.sentences {
                writeln!(out, "{s}")?;
            }
        }
        Err(e) => {
            writeln!(out, "ERR {e}")?;
        }
    }
    Ok(())
}

/// Fetch the server's one-line metrics report (a `::STATS::` request).
pub fn stats_remote(addr: std::net::SocketAddr) -> Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(format!("{STATS_MARKER}\n").as_bytes())?;
    let mut reader = BufReader::new(stream);
    let mut header = String::new();
    reader.read_line(&mut header)?;
    anyhow::ensure!(
        header.trim_end() == "OK 1",
        "unexpected stats header: {header:?}"
    );
    let mut report = String::new();
    reader.read_line(&mut report)?;
    Ok(report.trim_end().to_string())
}

/// Blocking client helper (used by tests, the serve demo and scripts).
pub fn summarize_remote(addr: std::net::SocketAddr, text: &str) -> Result<Vec<String>> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(text.as_bytes())?;
    stream.write_all(format!("\n{EOF_MARKER}\n").as_bytes())?;
    let mut reader = BufReader::new(stream);
    let mut header = String::new();
    reader.read_line(&mut header)?;
    let header = header.trim_end();
    if let Some(rest) = header.strip_prefix("OK ") {
        let m: usize = rest.parse().context("bad OK header")?;
        let mut out = Vec::with_capacity(m);
        for _ in 0..m {
            let mut line = String::new();
            reader.read_line(&mut line)?;
            out.push(line.trim_end().to_string());
        }
        Ok(out)
    } else {
        anyhow::bail!("server error: {header}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Settings;
    use crate::corpus::benchmark_set;

    #[test]
    fn tcp_round_trip() {
        let mut settings = Settings::default();
        settings.service.workers = 1;
        settings.pipeline.solver = "tabu".into();
        settings.pipeline.iterations = 2;
        let svc = Arc::new(Service::start(&settings).unwrap());
        let server = TcpServer::start(svc.clone(), 0).unwrap();

        let set = benchmark_set("cnn_dm_20").unwrap();
        let text = set.documents[0].text();
        let summary = summarize_remote(server.addr, &text).unwrap();
        assert_eq!(summary.len(), 6);
        // summary sentences come from the document
        for s in &summary {
            assert!(
                set.documents[0].sentences.iter().any(|d| d == s),
                "sentence not from document: {s}"
            );
        }
        server.stop();
    }

    #[test]
    fn tcp_error_for_tiny_document() {
        let mut settings = Settings::default();
        settings.service.workers = 1;
        settings.pipeline.solver = "tabu".into();
        settings.pipeline.iterations = 1;
        let svc = Arc::new(Service::start(&settings).unwrap());
        let server = TcpServer::start(svc.clone(), 0).unwrap();
        let err = summarize_remote(server.addr, "One sentence.").unwrap_err();
        assert!(err.to_string().contains("server error"), "{err}");
        server.stop();
    }

    #[test]
    fn tcp_stats_reports_pool_occupancy() {
        let mut settings = Settings::default();
        settings.service.workers = 2;
        settings.pipeline.solver = "tabu".into();
        settings.pipeline.iterations = 2;
        let svc = Arc::new(Service::start(&settings).unwrap());
        assert!(svc.is_pooled());
        let server = TcpServer::start(svc.clone(), 0).unwrap();
        let set = benchmark_set("cnn_dm_20").unwrap();
        summarize_remote(server.addr, &set.documents[0].text()).unwrap();
        let report = stats_remote(server.addr).unwrap();
        assert!(report.contains("completed=1"), "{report}");
        assert!(report.contains("occupancy"), "{report}");
        server.stop();
    }

    #[test]
    fn tcp_concurrent_clients() {
        let mut settings = Settings::default();
        settings.service.workers = 2;
        settings.pipeline.solver = "tabu".into();
        settings.pipeline.iterations = 1;
        let svc = Arc::new(Service::start(&settings).unwrap());
        let server = TcpServer::start(svc.clone(), 0).unwrap();
        let set = benchmark_set("cnn_dm_20").unwrap();
        let addr = server.addr;
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let text = set.documents[i].text();
                std::thread::spawn(move || summarize_remote(addr, &text).unwrap())
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap().len(), 6);
        }
        server.stop();
    }
}
