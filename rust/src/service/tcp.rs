//! TCP front-end: a line-oriented protocol over the summarization
//! service, making `cobi-es serve --port N` a real network endpoint for
//! edge deployments.
//!
//! Protocol (one request per connection, newline-framed):
//!   client sends the document text terminated by a line containing
//!   exactly `::EOF::`;
//!   server replies `OK <m>` followed by the m summary sentences (one per
//!   line) and closes, or `ERR <message>`.
//!
//! A first line of exactly `::STATS::` instead requests the service
//! metrics report (counts, latency percentiles, per-strategy totals and —
//! when the shared device pool is running — batch occupancy / coalescing
//! / utilization): the server replies `OK 1` followed by one report line.
//! `::STATS JSON::` is its machine-readable variant (`OK 1` + one JSON
//! line, schema in docs/OBSERVABILITY.md), and `::METRICS::` serves the
//! Prometheus-style text exposition — counters, latency histograms and
//! the fleet energy-ledger series — as `OK <n>` + n exposition lines.
//!
//! A header line of `::WORKLOAD <name>::` before the body routes the
//! request to a registered k-of-n workload instead of ES summarization
//! (see [`WORKLOAD_PREFIX`]): the body becomes one candidate per line
//! and the reply lists the selected candidates.
//!
//! A first line of exactly `::STREAM::` opens a `SUMMARIZE_STREAM`
//! session: the client sends document text in chunks, each terminated by
//! a `::CHUNK::` line; after every chunk the server replies with a
//! summary REVISION of everything received so far — `REV <m>` followed by
//! m sentences (`REV 0` while too few sentences have arrived). A final
//! `::EOF::` line (any trailing text before it counts as a last chunk)
//! closes the session with the final summary as `OK <m>` + m sentences.
//! Chunk boundaries must fall between sentences; revisions re-solve only
//! the rolling frontier, so arbitrarily long feeds stream in O(P) state.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::corpus::Document;

use super::overload::{Deadline, Shed, Tier};
use super::{Service, SubmitOptions};

/// Terminates a document (and closes a stream session).
pub const EOF_MARKER: &str = "::EOF::";
/// First-line marker requesting the metrics report.
pub const STATS_MARKER: &str = "::STATS::";
/// First-line marker requesting the machine-readable (JSON) stats.
pub const STATS_JSON_MARKER: &str = "::STATS JSON::";
/// First-line marker requesting the Prometheus-style exposition.
pub const METRICS_MARKER: &str = "::METRICS::";
/// First-line marker opening a `SUMMARIZE_STREAM` session.
pub const STREAM_MARKER: &str = "::STREAM::";
/// Ends one stream chunk and requests a summary revision.
pub const CHUNK_MARKER: &str = "::CHUNK::";
/// Header-line prefix carrying the request deadline: `::DEADLINE <ms>::`
/// before the document text.
pub const DEADLINE_PREFIX: &str = "::DEADLINE ";
/// Header line tagging the request batch-tier (first to shed under
/// pressure); sent before the document text.
pub const BATCH_MARKER: &str = "::BATCH::";
/// Admin frame requesting a graceful drain: the server stops accepting
/// new connections and the serve loop finishes in-flight work.
pub const DRAIN_MARKER: &str = "::DRAIN::";
/// Admin-frame prefix replaying a recorded request: `::REPLAY <id>::` as
/// the first line re-executes flight-recorder ring entry `id` through
/// the current binary and byte-diffs the outputs. The reply is `OK 1`
/// plus one verdict line (`identical` or the first divergent DAG node +
/// config-fingerprint diff — see docs/OBSERVABILITY.md), or `ERR` when
/// the recorder is off / the id fell out of the ring.
pub const REPLAY_PREFIX: &str = "::REPLAY ";
/// Header-line prefix routing the request to a registered k-of-n
/// workload: `::WORKLOAD <name>::` before the body. The body is then one
/// candidate per line (for `retrieval` the first line is the query; for
/// `dispersion` the single body line is an instance spec such as
/// `n=16 k=4 seed=7`), and the `OK <k>` reply lists the selected
/// candidates. Without this header the request is an ES summarize.
pub const WORKLOAD_PREFIX: &str = "::WORKLOAD ";

/// A running TCP endpoint over a Service.
pub struct TcpServer {
    /// Bound listen address.
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    drain: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl TcpServer {
    /// Bind and serve in background threads. Port 0 picks a free port.
    pub fn start(service: Arc<Service>, port: u16) -> Result<Self> {
        let listener =
            TcpListener::bind(("127.0.0.1", port)).context("binding tcp listener")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let drain = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let drain2 = drain.clone();
        let accept_thread = std::thread::Builder::new()
            .name("cobi-tcp-accept".into())
            .spawn(move || {
                let mut conn_id = 0u64;
                while !stop2.load(Ordering::SeqCst) && !drain2.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            conn_id += 1;
                            let svc = service.clone();
                            let drain = drain2.clone();
                            let id = conn_id;
                            // one thread per connection: edge workloads are
                            // low-concurrency; the Service queue is the
                            // real admission control
                            let _ = std::thread::Builder::new()
                                .name(format!("cobi-tcp-conn-{id}"))
                                .spawn(move || {
                                    let _ = handle_connection(&svc, stream, id, &drain);
                                });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Self {
            addr,
            stop,
            drain,
            accept_thread: Some(accept_thread),
        })
    }

    /// True once a `::DRAIN::` admin frame arrived (or
    /// [`TcpServer::shutdown`] ran): the accept loop has stopped taking
    /// new connections and the serve loop should drain the service.
    pub fn drain_requested(&self) -> bool {
        self.drain.load(Ordering::SeqCst)
    }

    /// Stop accepting and join the accept thread.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }

    /// Graceful shutdown: stop accepting new connections (like a
    /// `::DRAIN::` frame) and join the accept thread. The caller then
    /// drains the [`Service`] itself so in-flight requests finish.
    pub fn shutdown(self) {
        self.drain.store(true, Ordering::SeqCst);
        self.stop();
    }
}

/// Is this read error the connection idle-timeout firing?
fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

fn handle_connection(
    service: &Service,
    stream: TcpStream,
    id: u64,
    drain: &Arc<AtomicBool>,
) -> Result<()> {
    stream.set_read_timeout(service.idle_timeout())?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut text = String::new();
    let mut line = String::new();
    let mut first = true;
    let mut opts = SubmitOptions::default();
    let cap = service.max_doc_bytes();
    loop {
        line.clear();
        let n = match reader.read_line(&mut line) {
            Ok(n) => n,
            Err(e) if is_timeout(&e) => {
                // slow-loris / stalled writer: answer and hang up rather
                // than pinning a connection thread forever
                let mut out = stream;
                let _ = writeln!(out, "ERR idle timeout");
                return Ok(());
            }
            Err(e) => return Err(e.into()),
        };
        if first && line.trim_end() == STATS_MARKER {
            let mut out = stream;
            writeln!(out, "OK 1")?;
            writeln!(out, "{}", service.metrics().report())?;
            return Ok(());
        }
        if first && line.trim_end() == STATS_JSON_MARKER {
            let mut out = stream;
            writeln!(out, "OK 1")?;
            writeln!(out, "{}", crate::obs::export::stats_json(&service.metrics()))?;
            return Ok(());
        }
        if first && line.trim_end() == METRICS_MARKER {
            let mut out = stream;
            let body = crate::obs::export::exposition(&service.metrics());
            writeln!(out, "OK {}", body.lines().count())?;
            out.write_all(body.as_bytes())?;
            return Ok(());
        }
        if first && line.trim_end() == STREAM_MARKER {
            return handle_stream_session(service, reader, stream, id);
        }
        if first {
            if let Some(rest) = line.trim_end().strip_prefix(REPLAY_PREFIX) {
                let mut out = stream;
                match rest.strip_suffix("::").map(str::trim).map(str::parse::<u64>) {
                    Some(Ok(rec_id)) => match service.replay(rec_id) {
                        Ok(report) => {
                            writeln!(out, "OK 1")?;
                            writeln!(out, "{}", report.verdict_line())?;
                        }
                        Err(e) => writeln!(out, "ERR {e}")?,
                    },
                    _ => writeln!(out, "ERR bad replay frame: {}", line.trim_end())?,
                }
                return Ok(());
            }
        }
        if first && line.trim_end() == DRAIN_MARKER {
            // admin frame: stop accepting; the serve loop notices the
            // flag (`drain_requested`) and drains the service
            drain.store(true, Ordering::SeqCst);
            let mut out = stream;
            writeln!(out, "OK 0")?;
            return Ok(());
        }
        first = false;
        let trimmed = line.trim_end();
        if n == 0 || trimmed == EOF_MARKER {
            break;
        }
        // header lines before the document body
        if let Some(rest) = trimmed.strip_prefix(DEADLINE_PREFIX) {
            match rest.strip_suffix("::").map(str::trim).map(str::parse::<u64>) {
                Some(Ok(ms)) => {
                    opts.deadline = Some(Deadline::from_ms(ms));
                    continue;
                }
                _ => {
                    let mut out = stream;
                    writeln!(out, "ERR bad deadline header: {trimmed}")?;
                    return Ok(());
                }
            }
        }
        if trimmed == BATCH_MARKER {
            opts.tier = Tier::Batch;
            continue;
        }
        if let Some(rest) = trimmed.strip_prefix(WORKLOAD_PREFIX) {
            match rest
                .strip_suffix("::")
                .map(str::trim)
                .and_then(crate::workload::resolve)
            {
                Some(name) => {
                    opts.workload = name;
                    continue;
                }
                None => {
                    let mut out = stream;
                    writeln!(out, "ERR unknown workload: {trimmed}")?;
                    return Ok(());
                }
            }
        }
        if trimmed.starts_with("::") && trimmed.ends_with("::") && trimmed.len() > 4 {
            // any other ::marker:: here is a protocol error (::CHUNK::
            // without ::STREAM::, mid-document ::STATS::, typos): answer
            // cleanly instead of summarizing the marker as text
            let mut out = stream;
            writeln!(out, "ERR unknown marker: {trimmed}")?;
            return Ok(());
        }
        if let Some(cap) = cap {
            if text.len() + line.len() > cap {
                let mut out = stream;
                writeln!(out, "ERR document too large (over {cap} bytes)")?;
                return Ok(());
            }
        }
        text.push_str(&line);
    }
    let mut out = stream;
    if text.trim().is_empty() {
        writeln!(out, "ERR empty document")?;
        return Ok(());
    }
    let doc = if opts.workload.is_empty() {
        Document::from_text(&format!("tcp-{id}"), &text)
    } else {
        // workload requests are line-framed, not sentence-split: each
        // non-empty body line is one candidate (or header line) exactly
        // as sent, so selections echo client lines byte-for-byte
        Document {
            id: format!("tcp-{id}"),
            sentences: text
                .lines()
                .map(str::trim)
                .filter(|l| !l.is_empty())
                .map(String::from)
                .collect(),
            reference: Vec::new(),
        }
    };
    let reply = service
        .submit_with(doc, opts)
        .and_then(|ticket| ticket.wait());
    match reply {
        Ok(summary) => {
            writeln!(out, "OK {}", summary.sentences.len())?;
            for s in &summary.sentences {
                writeln!(out, "{s}")?;
            }
        }
        Err(e) => {
            if let Some(shed) = e.downcast_ref::<Shed>() {
                // machine-parseable backoff hint (seeded jitter)
                writeln!(out, "ERR RETRY {}", shed.retry_after_ms)?;
            } else {
                writeln!(out, "ERR {e}")?;
            }
        }
    }
    Ok(())
}

/// One open `SUMMARIZE_STREAM` connection: chunks in, revisions out.
fn handle_stream_session(
    service: &Service,
    mut reader: BufReader<TcpStream>,
    stream: TcpStream,
    id: u64,
) -> Result<()> {
    let mut out = stream;
    let mut session = match service.open_stream(&format!("tcp-stream-{id}")) {
        Ok(s) => s,
        Err(e) => {
            writeln!(out, "ERR {e}")?;
            return Ok(());
        }
    };
    let mut text = String::new();
    let mut line = String::new();
    loop {
        line.clear();
        let n = match reader.read_line(&mut line) {
            Ok(n) => n,
            Err(e) if is_timeout(&e) => {
                // a stalled feed ends the session; dropping it settles
                // the counters as failed (see ServiceStream::drop)
                let _ = writeln!(out, "ERR idle timeout");
                return Ok(());
            }
            Err(e) => return Err(e.into()),
        };
        let trimmed = line.trim_end();
        if n == 0 || trimmed == EOF_MARKER {
            // trailing text before ::EOF:: counts as a last chunk
            if let Err(e) = ingest(&mut session, &mut text) {
                writeln!(out, "ERR {e}")?;
                return Ok(());
            }
            match session.finish() {
                Ok(summary) => {
                    writeln!(out, "OK {}", summary.sentences.len())?;
                    for s in &summary.sentences {
                        writeln!(out, "{s}")?;
                    }
                }
                Err(e) => {
                    writeln!(out, "ERR {e}")?;
                }
            }
            return Ok(());
        }
        if trimmed == CHUNK_MARKER {
            if let Err(e) = ingest(&mut session, &mut text) {
                writeln!(out, "ERR {e}")?;
                return Ok(());
            }
            if session.can_summarize() {
                match session.revision() {
                    Ok(rev) => {
                        writeln!(out, "REV {}", rev.sentences.len())?;
                        for s in &rev.sentences {
                            writeln!(out, "{s}")?;
                        }
                    }
                    Err(e) => {
                        writeln!(out, "ERR {e}")?;
                        return Ok(());
                    }
                }
            } else {
                // not enough sentences yet: an empty revision, session
                // stays open
                writeln!(out, "REV 0")?;
            }
            continue;
        }
        text.push_str(&line);
    }
}

/// Feed the buffered chunk text (if any) into the session.
fn ingest(session: &mut crate::service::ServiceStream, text: &mut String) -> Result<()> {
    if !text.trim().is_empty() {
        session.push_text(text)?;
    }
    text.clear();
    Ok(())
}

/// Fetch the server's one-line metrics report (a `::STATS::` request).
pub fn stats_remote(addr: std::net::SocketAddr) -> Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(format!("{STATS_MARKER}\n").as_bytes())?;
    let mut reader = BufReader::new(stream);
    let mut header = String::new();
    reader.read_line(&mut header)?;
    anyhow::ensure!(
        header.trim_end() == "OK 1",
        "unexpected stats header: {header:?}"
    );
    let mut report = String::new();
    reader.read_line(&mut report)?;
    Ok(report.trim_end().to_string())
}

/// Fetch the machine-readable stats (a `::STATS JSON::` request): one
/// JSON object, parseable with [`crate::obs::json::JsonValue::parse`].
pub fn stats_json_remote(addr: std::net::SocketAddr) -> Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(format!("{STATS_JSON_MARKER}\n").as_bytes())?;
    let mut reader = BufReader::new(stream);
    let mut header = String::new();
    reader.read_line(&mut header)?;
    anyhow::ensure!(
        header.trim_end() == "OK 1",
        "unexpected stats-json header: {header:?}"
    );
    let mut body = String::new();
    reader.read_line(&mut body)?;
    Ok(body.trim_end().to_string())
}

/// Fetch the Prometheus-style exposition (a `::METRICS::` request):
/// the newline-joined exposition lines, trailing newline included.
pub fn metrics_remote(addr: std::net::SocketAddr) -> Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(format!("{METRICS_MARKER}\n").as_bytes())?;
    let mut reader = BufReader::new(stream);
    let mut header = String::new();
    reader.read_line(&mut header)?;
    let n: usize = header
        .trim_end()
        .strip_prefix("OK ")
        .with_context(|| format!("unexpected metrics header: {header:?}"))?
        .parse()
        .context("bad metrics header count")?;
    let mut body = String::with_capacity(n * 48);
    for _ in 0..n {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        body.push_str(&line);
    }
    Ok(body)
}

/// Replay flight-recorder ring entry `id` on the server (a
/// `::REPLAY <id>::` admin frame): returns the one-line verdict
/// (`verdict=identical` or `verdict=DIVERGED` plus triage detail — see
/// [`crate::obs::ReplayReport::verdict_line`]).
pub fn replay_remote(addr: std::net::SocketAddr, id: u64) -> Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(format!("{REPLAY_PREFIX}{id}::\n").as_bytes())?;
    let mut reader = BufReader::new(stream);
    let mut header = String::new();
    reader.read_line(&mut header)?;
    anyhow::ensure!(
        header.trim_end() == "OK 1",
        "replay error: {}",
        header.trim_end()
    );
    let mut verdict = String::new();
    reader.read_line(&mut verdict)?;
    Ok(verdict.trim_end().to_string())
}

/// Read one framed reply: `REV <n>` / `OK <n>` followed by n sentence
/// lines, or `ERR <message>` (an error).
fn read_reply(reader: &mut BufReader<TcpStream>) -> Result<(&'static str, Vec<String>)> {
    let mut header = String::new();
    reader.read_line(&mut header)?;
    let header = header.trim_end();
    let (tag, rest) = if let Some(rest) = header.strip_prefix("REV ") {
        ("REV", rest)
    } else if let Some(rest) = header.strip_prefix("OK ") {
        ("OK", rest)
    } else {
        anyhow::bail!("server error: {header}");
    };
    let n: usize = rest.parse().context("bad reply header")?;
    let mut lines = Vec::with_capacity(n);
    for _ in 0..n {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        lines.push(line.trim_end().to_string());
    }
    Ok((tag, lines))
}

/// Blocking stream-session client: send `chunks` through a `::STREAM::`
/// session; returns (one summary revision per chunk — empty while too
/// few sentences have arrived — and the final summary).
pub fn stream_remote(
    addr: std::net::SocketAddr,
    chunks: &[&str],
) -> Result<(Vec<Vec<String>>, Vec<String>)> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(format!("{STREAM_MARKER}\n").as_bytes())?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut revisions = Vec::with_capacity(chunks.len());
    for chunk in chunks {
        stream.write_all(chunk.as_bytes())?;
        stream.write_all(format!("\n{CHUNK_MARKER}\n").as_bytes())?;
        let (tag, lines) = read_reply(&mut reader)?;
        anyhow::ensure!(tag == "REV", "expected a REV reply, got {tag}");
        revisions.push(lines);
    }
    stream.write_all(format!("{EOF_MARKER}\n").as_bytes())?;
    let (tag, lines) = read_reply(&mut reader)?;
    anyhow::ensure!(tag == "OK", "expected the final OK reply, got {tag}");
    Ok((revisions, lines))
}

/// Blocking client helper (used by tests, the serve demo and scripts).
pub fn summarize_remote(addr: std::net::SocketAddr, text: &str) -> Result<Vec<String>> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(text.as_bytes())?;
    stream.write_all(format!("\n{EOF_MARKER}\n").as_bytes())?;
    let mut reader = BufReader::new(stream);
    let mut header = String::new();
    reader.read_line(&mut header)?;
    let header = header.trim_end();
    if let Some(rest) = header.strip_prefix("OK ") {
        let m: usize = rest.parse().context("bad OK header")?;
        let mut out = Vec::with_capacity(m);
        for _ in 0..m {
            let mut line = String::new();
            reader.read_line(&mut line)?;
            out.push(line.trim_end().to_string());
        }
        Ok(out)
    } else {
        anyhow::bail!("server error: {header}");
    }
}

/// Blocking client for a `::WORKLOAD <name>::` request: sends the header
/// plus one body line per entry (for `retrieval`: the query first, then
/// the candidate passages; for `dispersion`: one instance-spec line);
/// returns the selected candidate lines from the `OK <k>` reply.
pub fn select_remote(
    addr: std::net::SocketAddr,
    workload: &str,
    lines: &[&str],
) -> Result<Vec<String>> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(format!("{WORKLOAD_PREFIX}{workload}::\n").as_bytes())?;
    for l in lines {
        stream.write_all(l.as_bytes())?;
        stream.write_all(b"\n")?;
    }
    stream.write_all(format!("{EOF_MARKER}\n").as_bytes())?;
    let mut reader = BufReader::new(stream);
    let (tag, out) = read_reply(&mut reader)?;
    anyhow::ensure!(tag == "OK", "expected an OK reply, got {tag}");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Settings;
    use crate::corpus::benchmark_set;

    #[test]
    fn tcp_round_trip() {
        let mut settings = Settings::default();
        settings.service.workers = 1;
        settings.pipeline.solver = "tabu".into();
        settings.pipeline.iterations = 2;
        let svc = Arc::new(Service::start(&settings).unwrap());
        let server = TcpServer::start(svc.clone(), 0).unwrap();

        let set = benchmark_set("cnn_dm_20").unwrap();
        let text = set.documents[0].text();
        let summary = summarize_remote(server.addr, &text).unwrap();
        assert_eq!(summary.len(), 6);
        // summary sentences come from the document
        for s in &summary {
            assert!(
                set.documents[0].sentences.iter().any(|d| d == s),
                "sentence not from document: {s}"
            );
        }
        server.stop();
    }

    #[test]
    fn tcp_error_for_tiny_document() {
        let mut settings = Settings::default();
        settings.service.workers = 1;
        settings.pipeline.solver = "tabu".into();
        settings.pipeline.iterations = 1;
        let svc = Arc::new(Service::start(&settings).unwrap());
        let server = TcpServer::start(svc.clone(), 0).unwrap();
        let err = summarize_remote(server.addr, "One sentence.").unwrap_err();
        assert!(err.to_string().contains("server error"), "{err}");
        server.stop();
    }

    #[test]
    fn tcp_stats_reports_pool_occupancy() {
        let mut settings = Settings::default();
        settings.service.workers = 2;
        settings.pipeline.solver = "tabu".into();
        settings.pipeline.iterations = 2;
        let svc = Arc::new(Service::start(&settings).unwrap());
        assert!(svc.is_pooled());
        let server = TcpServer::start(svc.clone(), 0).unwrap();
        let set = benchmark_set("cnn_dm_20").unwrap();
        summarize_remote(server.addr, &set.documents[0].text()).unwrap();
        let report = stats_remote(server.addr).unwrap();
        assert!(report.contains("completed=1"), "{report}");
        assert!(report.contains("occupancy"), "{report}");
        server.stop();
    }

    #[test]
    fn tcp_metrics_exposition_and_stats_json_round_trip() {
        let mut settings = Settings::default();
        settings.service.workers = 1;
        settings.pipeline.solver = "tabu".into();
        settings.pipeline.iterations = 2;
        settings.obs.enabled = true;
        let svc = Arc::new(Service::start(&settings).unwrap());
        let server = TcpServer::start(svc.clone(), 0).unwrap();
        let set = benchmark_set("cnn_dm_20").unwrap();
        summarize_remote(server.addr, &set.documents[0].text()).unwrap();

        // exposition: request counters + the energy-ledger series
        let exposition = metrics_remote(server.addr).unwrap();
        assert!(
            exposition.contains("cobi_es_requests_total{state=\"completed\"} 1"),
            "{exposition}"
        );
        assert!(
            exposition.contains("cobi_es_energy_joules_total{backend=\"tabu\""),
            "{exposition}"
        );
        assert!(exposition.contains("cobi_es_solve_seconds_bucket"), "{exposition}");

        // stats json: parses, and its counters round-trip the report's
        let body = stats_json_remote(server.addr).unwrap();
        let v = crate::obs::json::JsonValue::parse(&body).unwrap();
        let req = v.get("requests").unwrap();
        assert_eq!(req.get("completed").unwrap().as_u64(), Some(1));
        assert_eq!(req.get("submitted").unwrap().as_u64(), Some(1));
        let obs = v.get("obs").unwrap();
        assert_eq!(obs.get("tracing").unwrap().as_bool(), Some(true));
        assert!(obs.get("energy_j").unwrap().as_f64().unwrap() > 0.0);
        server.stop();
    }

    #[test]
    fn tcp_stream_session_revises_and_finishes() {
        let mut settings = Settings::default();
        settings.service.workers = 1;
        settings.pipeline.solver = "tabu".into();
        settings.pipeline.iterations = 2;
        let svc = Arc::new(Service::start(&settings).unwrap());
        let server = TcpServer::start(svc.clone(), 0).unwrap();

        let set = benchmark_set("cnn_dm_50").unwrap();
        let doc = &set.documents[0];
        // three chunks on sentence boundaries
        let c1 = doc.sentences[..10].join(" ");
        let c2 = doc.sentences[10..30].join(" ");
        let c3 = doc.sentences[30..].join(" ");
        let (revisions, fin) =
            stream_remote(server.addr, &[&c1, &c2, &c3]).unwrap();
        assert_eq!(revisions.len(), 3);
        for rev in &revisions {
            assert_eq!(rev.len(), 6, "each chunk yields a full revision");
        }
        assert_eq!(fin.len(), 6);
        for s in &fin {
            assert!(
                doc.sentences.iter().any(|d| d == s),
                "sentence not from document: {s}"
            );
        }
        // revisions over longer prefixes may differ, the final summary
        // matches a whole-document stream of the same session seed
        let report = stats_remote(server.addr).unwrap();
        assert!(report.contains("sessions=1"), "{report}");
        assert!(report.contains("revisions=4"), "{report}");
        server.stop();
    }

    #[test]
    fn tcp_stream_session_reports_empty_revision_when_too_short() {
        let mut settings = Settings::default();
        settings.service.workers = 1;
        settings.pipeline.solver = "tabu".into();
        settings.pipeline.iterations = 1;
        settings.pipeline.summary_len = 3;
        let svc = Arc::new(Service::start(&settings).unwrap());
        let server = TcpServer::start(svc.clone(), 0).unwrap();
        // one sentence: first revision must be empty, and the session
        // still errors cleanly at EOF (frontier < summary_len)
        let mut stream = std::net::TcpStream::connect(server.addr).unwrap();
        stream
            .write_all(format!("{STREAM_MARKER}\nOne sentence only.\n{CHUNK_MARKER}\n").as_bytes())
            .unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim_end(), "REV 0");
        stream
            .write_all(format!("{EOF_MARKER}\n").as_bytes())
            .unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR"), "{line}");
        server.stop();
    }

    /// Write `payload` raw, read back the first reply line.
    fn raw_request(addr: std::net::SocketAddr, payload: &str) -> String {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(payload.as_bytes()).unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        line.trim_end().to_string()
    }

    #[test]
    fn tcp_overload_frames_round_trip() {
        let mut settings = Settings::default();
        settings.service.workers = 1;
        settings.pipeline.solver = "tabu".into();
        settings.pipeline.iterations = 1;
        let svc = Arc::new(Service::start(&settings).unwrap());
        let server = TcpServer::start(svc.clone(), 0).unwrap();
        let set = benchmark_set("cnn_dm_20").unwrap();
        let text = set.documents[0].text();

        // generous deadline + batch tag: still serves normally
        let line = raw_request(
            server.addr,
            &format!("::DEADLINE 60000::\n{BATCH_MARKER}\n{text}\n{EOF_MARKER}\n"),
        );
        assert_eq!(line, "OK 6", "{line}");

        // already-expired deadline: typed, clean error
        let line = raw_request(
            server.addr,
            &format!("::DEADLINE 0::\n{text}\n{EOF_MARKER}\n"),
        );
        assert!(line.starts_with("ERR deadline exceeded"), "{line}");

        // malformed deadline header
        let line = raw_request(server.addr, "::DEADLINE soon::\n");
        assert!(line.contains("bad deadline header"), "{line}");

        // a chunk marker with no prior ::STREAM:: is a protocol error
        let line = raw_request(server.addr, &format!("some text\n{CHUNK_MARKER}\n"));
        assert!(line.contains("unknown marker"), "{line}");

        // empty document: clean error without burning a solve
        let line = raw_request(server.addr, &format!("{EOF_MARKER}\n"));
        assert!(line.contains("empty document"), "{line}");

        let m = svc.metrics();
        assert_eq!(m.completed, 1);
        assert_eq!(m.overload.deadline_exceeded, 1);
        server.stop();
    }

    #[test]
    fn tcp_workload_request_selects_candidate_lines() {
        let mut settings = Settings::default();
        settings.service.workers = 1;
        settings.pipeline.solver = "tabu".into();
        settings.pipeline.iterations = 2;
        let svc = Arc::new(Service::start(&settings).unwrap());
        let server = TcpServer::start(svc.clone(), 0).unwrap();

        let lines = [
            "ising machines for combinatorial optimization",
            "the cmos ising chip anneals coupled spins",
            "a recipe for sourdough bread with rye flour",
            "quantum annealers embed qubo problems",
            "league standings after the weekend fixtures",
            "simulated annealing is a classical baseline",
            "gardening tips for late-summer tomatoes",
        ];
        let selected = select_remote(server.addr, "retrieval", &lines).unwrap();
        assert_eq!(selected.len(), settings.workload.retrieval_k);
        for s in &selected {
            assert!(
                lines[1..].contains(&s.as_str()),
                "selected line not a candidate passage: {s}"
            );
        }
        // a second identical request selects identically (seeded end to end)
        let again = select_remote(server.addr, "retrieval", &lines).unwrap();
        assert_eq!(selected, again);

        // dispersion: one spec line in, k site lines out
        let sites = select_remote(server.addr, "dispersion", &["n=12 k=3 seed=9"]).unwrap();
        assert_eq!(sites.len(), 3);

        // workload completions surface in the stats report
        let report = stats_remote(server.addr).unwrap();
        assert!(report.contains("workload es=0 retrieval=2 dispersion=1"), "{report}");
        server.stop();
    }

    #[test]
    fn tcp_unknown_workload_is_a_clean_error() {
        let mut settings = Settings::default();
        settings.service.workers = 1;
        settings.pipeline.solver = "tabu".into();
        settings.pipeline.iterations = 1;
        let svc = Arc::new(Service::start(&settings).unwrap());
        let server = TcpServer::start(svc.clone(), 0).unwrap();
        let line = raw_request(server.addr, "::WORKLOAD nope::\n");
        assert!(line.contains("unknown workload"), "{line}");
        // a retrieval request with no passages fails without crashing
        let err = select_remote(server.addr, "retrieval", &["query only"]).unwrap_err();
        assert!(err.to_string().contains("server error"), "{err}");
        assert_eq!(svc.metrics().completed, 0);
        server.stop();
    }

    #[test]
    fn tcp_document_size_cap_rejects_oversized_docs() {
        let mut settings = Settings::default();
        settings.service.workers = 1;
        settings.service.max_doc_bytes = 256;
        settings.pipeline.solver = "tabu".into();
        settings.pipeline.iterations = 1;
        let svc = Arc::new(Service::start(&settings).unwrap());
        let server = TcpServer::start(svc.clone(), 0).unwrap();
        let big = "A sentence of filler text for the size cap. ".repeat(40);
        let line = raw_request(server.addr, &format!("{big}\n{EOF_MARKER}\n"));
        assert!(line.contains("document too large"), "{line}");
        assert_eq!(svc.metrics().submitted, 0, "capped doc must not submit");
        server.stop();
    }

    #[test]
    fn tcp_drain_frame_stops_accepts() {
        let mut settings = Settings::default();
        settings.service.workers = 1;
        settings.pipeline.solver = "tabu".into();
        settings.pipeline.iterations = 1;
        let svc = Arc::new(Service::start(&settings).unwrap());
        let server = TcpServer::start(svc.clone(), 0).unwrap();
        assert!(!server.drain_requested());
        let line = raw_request(server.addr, &format!("{DRAIN_MARKER}\n"));
        assert_eq!(line, "OK 0");
        assert!(server.drain_requested());
        server.stop();
    }

    #[test]
    fn tcp_replay_frame_round_trips_a_recorded_request() {
        let mut settings = Settings::default();
        settings.service.workers = 1;
        settings.pipeline.solver = "tabu".into();
        settings.pipeline.iterations = 2;
        settings.pipeline.summary_len = 3;
        settings.obs.record_enabled = true;
        let svc = Arc::new(Service::start(&settings).unwrap());
        let server = TcpServer::start(svc.clone(), 0).unwrap();

        // replaying before anything was recorded names the empty ring
        let err = replay_remote(server.addr, 1).unwrap_err();
        assert!(err.to_string().contains("no record 1"), "{err}");

        let set = benchmark_set("bench_10").unwrap();
        summarize_remote(server.addr, &set.documents[0].text()).unwrap();
        let verdict = replay_remote(server.addr, 1).unwrap();
        assert!(verdict.contains("verdict=identical"), "{verdict}");
        assert!(verdict.contains("id=1"), "{verdict}");

        // malformed frames answer cleanly
        let line = raw_request(server.addr, "::REPLAY soon::\n");
        assert!(line.contains("bad replay frame"), "{line}");
        server.stop();
    }

    #[test]
    fn tcp_replay_frame_errors_when_recorder_disabled() {
        let mut settings = Settings::default();
        settings.service.workers = 1;
        settings.pipeline.solver = "tabu".into();
        settings.pipeline.iterations = 1;
        let svc = Arc::new(Service::start(&settings).unwrap());
        let server = TcpServer::start(svc.clone(), 0).unwrap();
        let err = replay_remote(server.addr, 1).unwrap_err();
        assert!(err.to_string().contains("disabled"), "{err}");
        server.stop();
    }

    #[test]
    fn tcp_concurrent_clients() {
        let mut settings = Settings::default();
        settings.service.workers = 2;
        settings.pipeline.solver = "tabu".into();
        settings.pipeline.iterations = 1;
        let svc = Arc::new(Service::start(&settings).unwrap());
        let server = TcpServer::start(svc.clone(), 0).unwrap();
        let set = benchmark_set("cnn_dm_20").unwrap();
        let addr = server.addr;
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let text = set.documents[i].text();
                std::thread::spawn(move || summarize_remote(addr, &text).unwrap())
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap().len(), 6);
        }
        server.stop();
    }
}
