//! Worker pool: workers drain the shared request queue and run the
//! embed/formulate/quantize/refine stages. Ising solves take one of two
//! routes:
//!
//!   * `Pooled` (default for pool-capable solvers): the worker walks the
//!     document's `sched::SubproblemGraph` and submits every ready
//!     window's refinement batch to the shared `DevicePool`, so solves
//!     from ALL in-flight documents coalesce on the devices. Seeds are
//!     per-document (`sched::doc_seed`), making results independent of
//!     worker assignment and dispatch order.
//!   * `Local` (pool disabled, or brute/exact/random solvers): each
//!     worker owns a full `EsPipeline` + private solver, as before.
//!
//! A single shared receiver behind a mutex gives natural work-stealing
//! load balance without a router thread.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::Settings;
use crate::corpus::Document;
use crate::obs::{ObsShared, Span};
use crate::pipeline::{EsPipeline, Summary};
use crate::resilience::ResilienceShared;
use crate::runtime::ArtifactRuntime;
use crate::sched::{self, PoolHandle};

use super::metrics::ServiceMetrics;
use super::overload::{AdmissionController, Deadline, DeadlineExceeded, Tier};

/// One queued request.
pub struct Job {
    /// Request id.
    pub id: u64,
    /// The document to summarize.
    pub doc: Document,
    /// One-shot reply channel.
    pub respond: SyncSender<Result<Summary>>,
    /// Submission time (queue-wait accounting).
    pub enqueued: Instant,
    /// Admission tier the request was accepted under.
    pub tier: Tier,
    /// End-to-end deadline; checked before dequeue-to-solve and again at
    /// every pool dispatch level, so expired work never burns device time.
    pub deadline: Option<Deadline>,
    /// Registered workload name; empty = ES (the legacy text path).
    pub workload: &'static str,
}

/// How workers perform Ising solves.
pub enum SolveRoute {
    /// Worker-private pipeline + solver (seed derived from worker slot).
    Local,
    /// Shared device pool; per-document seeds.
    Pooled(PoolHandle),
}

/// Spawn the worker threads per `settings.service`.
#[allow(clippy::too_many_arguments)]
pub fn spawn_workers(
    settings: &Settings,
    rx: Receiver<Job>,
    metrics: Arc<Mutex<ServiceMetrics>>,
    inflight: Arc<AtomicUsize>,
    stop: Arc<AtomicBool>,
    route: SolveRoute,
    rt: Option<&ArtifactRuntime>,
    resilience: Option<&ResilienceShared>,
    obs: &ObsShared,
    admission: Arc<AdmissionController>,
) -> Result<Vec<std::thread::JoinHandle<()>>> {
    let shared_rx = Arc::new(Mutex::new(rx));
    let mut handles = Vec::new();
    let pool_handle = match &route {
        SolveRoute::Pooled(h) => Some(h.clone()),
        SolveRoute::Local => None,
    };
    for w in 0..settings.service.workers.max(1) {
        let rx = shared_rx.clone();
        let metrics = metrics.clone();
        let inflight = inflight.clone();
        let stop = stop.clone();
        let max_batch = settings.service.max_batch.max(1);
        let base_cfg = settings.pipeline.clone();

        // per-worker solve function: takes the request's queue wait so
        // the finished trace carries end-to-end latency, not just solve,
        // plus the deadline/tier the job was admitted under
        let mut solve: SolveFn = match &pool_handle {
            Some(handle) => {
                let handle = handle.clone();
                let obs = obs.clone();
                let workload_cfg = settings.workload.clone();
                Box::new(
                    move |doc: &Document,
                          queue_wait: Duration,
                          deadline: Option<Deadline>,
                          tier: Tier,
                          workload: &'static str| {
                        if !workload.is_empty() && workload != "es" {
                            // non-ES workload: the body lines travel in
                            // doc.sentences; build the problem and route
                            // it through the platform seam (salted seed,
                            // tagged pool client). Deadlines are checked
                            // at the queue boundary; re-check here since
                            // this path sets no client deadline.
                            if let Some(d) = deadline {
                                if d.expired() {
                                    return Err(d.exceeded().into());
                                }
                            }
                            let problem = crate::workload::problem_from_request(
                                workload,
                                &doc.id,
                                &doc.sentences,
                                &workload_cfg,
                            )?;
                            let t0 = Instant::now();
                            let (summary, mut root) = crate::workload::select_with_pool_obs(
                                problem.as_ref(),
                                &base_cfg,
                                &handle,
                                Some(&obs),
                            )?;
                            if let Some(r) = root.as_mut() {
                                r.set("tier", tier.as_str());
                            }
                            let recorder = obs.recorder();
                            if recorder.enabled() {
                                let mut rec = recorder.begin(
                                    &doc.id,
                                    &doc.sentences,
                                    crate::workload::problem_seed(
                                        base_cfg.seed,
                                        workload,
                                        &doc.id,
                                    ),
                                    workload,
                                    effective_strategy(base_cfg.strategy).as_str(),
                                    "pooled",
                                    tier.as_str(),
                                    deadline.map(|d| d.budget_ms()).unwrap_or(0),
                                );
                                rec.finish(&summary);
                                recorder.record(rec);
                            }
                            obs.finish_request(
                                root,
                                &doc.id,
                                queue_wait.as_secs_f64(),
                                t0.elapsed().as_secs_f64(),
                            );
                            return Ok(summary);
                        }
                        // seeds keyed to the DOCUMENT: any worker produces
                        // the same bytes for the same (config, doc)
                        let seed = sched::doc_seed(base_cfg.seed, &doc.id);
                        let mut cfg = base_cfg.clone();
                        cfg.seed = seed;
                        let mut client = handle.client(seed);
                        // the executor re-checks this before every DAG
                        // level, so deep documents stop mid-flight too
                        client.set_deadline(deadline);
                        let t0 = Instant::now();
                        let recorder = obs.recorder();
                        let (summary, mut root) = if recorder.enabled() {
                            // recording path: identical execution plus the
                            // per-node taps (enabled-off requests take the
                            // branch below and allocate nothing extra)
                            let mut rec = recorder.begin(
                                &doc.id,
                                &doc.sentences,
                                seed,
                                "es",
                                cfg.strategy.as_str(),
                                "pooled",
                                tier.as_str(),
                                deadline.map(|d| d.budget_ms()).unwrap_or(0),
                            );
                            let out = sched::summarize_with_pool_recorded(
                                doc,
                                &cfg,
                                &mut client,
                                &obs,
                                &mut rec.nodes,
                            )?;
                            rec.finish(&out.0);
                            recorder.record(rec);
                            out
                        } else {
                            sched::summarize_with_pool_traced(doc, &cfg, &mut client, &obs)?
                        };
                        if let Some(r) = root.as_mut() {
                            r.set("tier", tier.as_str());
                            if let Some(d) = deadline {
                                r.set("deadline_ms", d.budget_ms());
                            }
                        }
                        obs.finish_request(
                            root,
                            &doc.id,
                            queue_wait.as_secs_f64(),
                            t0.elapsed().as_secs_f64(),
                        );
                        Ok(summary)
                    },
                )
            }
            None => {
                // per-worker pipeline: derived seed keeps workers
                // decorrelated but the fleet reproducible. Pipelines
                // are built HERE (caller's stack), so the borrowed
                // artifact runtime never crosses into the threads —
                // executables are Arc-owned by construction time.
                // The resilience layer / fault model applies to the
                // local route exactly like the pooled one
                // (`resilient_pipeline` is the shared decision).
                let mut cfg = base_cfg.clone();
                cfg.seed = cfg.seed.wrapping_add(w as u64 * 0x9E37);
                let mut pipeline = match crate::resilience::resilient_pipeline(
                    settings,
                    &cfg,
                    rt,
                    resilience,
                    Some((obs, crate::obs::Subsystem::Pipeline)),
                )? {
                    Some(p) => p,
                    None => EsPipeline::from_config(&cfg, &settings.cobi, rt)?,
                };
                let obs = obs.clone();
                let strategy = cfg.strategy;
                // the seed the worker's pipeline ACTUALLY solves under
                // (worker-salted) — what a replay must reproduce
                let local_seed = cfg.seed;
                let local_settings = settings.clone();
                Box::new(
                    move |doc: &Document,
                          queue_wait: Duration,
                          deadline: Option<Deadline>,
                          tier: Tier,
                          workload: &'static str| {
                        // the local pipeline is opaque to per-unit spans:
                        // trace at request granularity (route + score).
                        // Deadlines are enforced at the queue boundary
                        // (worker_loop pre-checks before solving) — the
                        // monolithic pipeline has no dispatch seams to
                        // re-check at, so check once more here.
                        if let Some(d) = deadline {
                            if d.expired() {
                                return Err(d.exceeded().into());
                            }
                        }
                        if !workload.is_empty() && workload != "es" {
                            // non-ES on the local route: a fresh inline
                            // solver per request (the worker's pipeline
                            // is an ES text pipeline); solves are charged
                            // to the workload's ledger subsystem. The HLO
                            // artifact runtime cannot cross into worker
                            // threads, so workload requests run the
                            // native backends here.
                            let problem = crate::workload::problem_from_request(
                                workload,
                                &doc.id,
                                &doc.sentences,
                                &local_settings.workload,
                            )?;
                            let t0 = Instant::now();
                            let (summary, mut root) = crate::workload::select_inline_obs(
                                problem.as_ref(),
                                &local_settings,
                                None,
                                Some(&obs),
                            )?;
                            if let Some(r) = root.as_mut() {
                                r.set("tier", tier.as_str());
                            }
                            let recorder = obs.recorder();
                            if recorder.enabled() {
                                let mut rec = recorder.begin(
                                    &doc.id,
                                    &doc.sentences,
                                    crate::workload::problem_seed(
                                        local_settings.pipeline.seed,
                                        workload,
                                        &doc.id,
                                    ),
                                    workload,
                                    effective_strategy(strategy).as_str(),
                                    "local",
                                    tier.as_str(),
                                    deadline.map(|d| d.budget_ms()).unwrap_or(0),
                                );
                                rec.finish(&summary);
                                recorder.record(rec);
                            }
                            obs.finish_request(
                                root,
                                &doc.id,
                                queue_wait.as_secs_f64(),
                                t0.elapsed().as_secs_f64(),
                            );
                            return Ok(summary);
                        }
                        let mut root = obs.start_request(&doc.id);
                        if let Some(r) = root.as_mut() {
                            r.set("route", "local");
                            r.set("strategy", strategy.as_str());
                            r.set("tier", tier.as_str());
                        }
                        let t0 = Instant::now();
                        let summary = pipeline.summarize(doc)?;
                        if let Some(r) = root.as_mut() {
                            r.push(
                                Span::new("score")
                                    .with("objective", summary.objective)
                                    .with("selected", summary.selected.len())
                                    .with("solves", summary.total_solves),
                            );
                        }
                        let recorder = obs.recorder();
                        if recorder.enabled() {
                            // the monolithic pipeline exposes no per-node
                            // taps: local-route records triage at summary
                            // granularity (nodes stay empty)
                            let mut rec = recorder.begin(
                                &doc.id,
                                &doc.sentences,
                                local_seed,
                                "es",
                                strategy.as_str(),
                                "local",
                                tier.as_str(),
                                deadline.map(|d| d.budget_ms()).unwrap_or(0),
                            );
                            rec.finish(&summary);
                            recorder.record(rec);
                        }
                        obs.finish_request(
                            root,
                            &doc.id,
                            queue_wait.as_secs_f64(),
                            t0.elapsed().as_secs_f64(),
                        );
                        Ok(summary)
                    },
                )
            }
        };

        let strategy = settings.pipeline.strategy;
        let admission = admission.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("cobi-worker-{w}"))
                .spawn(move || {
                    worker_loop(
                        &mut *solve,
                        &rx,
                        &metrics,
                        &inflight,
                        &stop,
                        &admission,
                        max_batch,
                        strategy,
                    )
                })?,
        );
    }
    Ok(handles)
}

/// The strategy a non-ES request actually runs: `workload::lower`
/// coerces `Streaming` to `Window` (the streaming path embeds text
/// incrementally and cannot accept precomputed scores), so flight
/// records must carry the effective value or replay would re-coerce
/// a lie.
fn effective_strategy(s: crate::decompose::Strategy) -> crate::decompose::Strategy {
    if s == crate::decompose::Strategy::Streaming {
        crate::decompose::Strategy::Window
    } else {
        s
    }
}

/// Per-worker solve function: (document, queue wait, deadline, tier,
/// workload name — empty for ES).
type SolveFn = Box<
    dyn FnMut(&Document, Duration, Option<Deadline>, Tier, &'static str) -> Result<Summary> + Send,
>;

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    solve: &mut dyn FnMut(&Document, Duration, Option<Deadline>, Tier, &'static str) -> Result<Summary>,
    rx: &Arc<Mutex<Receiver<Job>>>,
    metrics: &Arc<Mutex<ServiceMetrics>>,
    inflight: &Arc<AtomicUsize>,
    stop: &Arc<AtomicBool>,
    admission: &AdmissionController,
    max_batch: usize,
    strategy: crate::decompose::Strategy,
) {
    loop {
        // pull a batch: one blocking recv, then drain up to max_batch-1.
        // The shared receiver outlives any single worker: a sibling that
        // panicked while holding the lock poisons the mutex, but the
        // channel itself is intact, so recover the guard instead of
        // cascading the panic through the whole pool.
        let mut batch = Vec::with_capacity(max_batch);
        {
            let guard = rx.lock().unwrap_or_else(PoisonError::into_inner);
            match guard.recv() {
                Ok(job) => batch.push(job),
                Err(_) => return, // queue closed: drain complete
            }
            while batch.len() < max_batch {
                match guard.try_recv() {
                    Ok(job) => batch.push(job),
                    Err(_) => break,
                }
            }
        } // release the lock before the (long) solves

        for job in batch {
            if stop.load(Ordering::SeqCst) {
                // shutting down: fail fast instead of burning device time
                let _ = job.respond.try_send(Err(anyhow::anyhow!("shutting down")));
                inflight.fetch_sub(1, Ordering::Relaxed);
                continue;
            }
            if let Some(d) = job.deadline {
                if d.expired() {
                    // the budget died in the queue: answer with the typed
                    // error without charging a latency sample (it would
                    // skew the solve histogram with zero-work entries)
                    let mut m = metrics.lock().unwrap();
                    m.failed += 1;
                    m.overload.deadline_exceeded += 1;
                    drop(m);
                    inflight.fetch_sub(1, Ordering::Relaxed);
                    let _ = job.respond.try_send(Err(d.exceeded().into()));
                    continue;
                }
            }
            let queue_wait = job.enqueued.elapsed();
            let t0 = Instant::now();
            // contain solver panics to the request: the worker answers
            // with an error and lives on to serve the next job, instead
            // of taking its thread (and a share of fleet capacity) down
            let result = catch_unwind(AssertUnwindSafe(|| {
                solve(&job.doc, queue_wait, job.deadline, job.tier, job.workload)
            }))
            .unwrap_or_else(|_| {
                metrics
                    .lock()
                    .unwrap_or_else(PoisonError::into_inner)
                    .overload
                    .worker_panics += 1;
                Err(anyhow::anyhow!("worker panicked during solve"))
            });
            let solve_time = t0.elapsed();
            {
                let mut m = metrics.lock().unwrap_or_else(PoisonError::into_inner);
                match &result {
                    Ok(_) => {
                        m.completed += 1;
                        m.strategies.record(strategy);
                        m.workloads.record(job.workload);
                    }
                    Err(e) => {
                        m.failed += 1;
                        if e.downcast_ref::<DeadlineExceeded>().is_some() {
                            // expired mid-solve (pool dispatch seam)
                            m.overload.deadline_exceeded += 1;
                        }
                    }
                }
                m.record_latency(queue_wait, solve_time);
            }
            if result.is_ok() {
                // feed the admission controller's wait estimator with
                // real solve times (failures are often fast-fail and
                // would bias the estimate low)
                admission.observe_solve(solve_time);
            }
            inflight.fetch_sub(1, Ordering::Relaxed);
            let _ = job.respond.try_send(result);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServiceConfig;
    use std::sync::mpsc::sync_channel;

    struct Harness {
        tx: SyncSender<Job>,
        rx: Arc<Mutex<Receiver<Job>>>,
        metrics: Arc<Mutex<ServiceMetrics>>,
        inflight: Arc<AtomicUsize>,
        stop: Arc<AtomicBool>,
        admission: Arc<AdmissionController>,
    }

    fn harness() -> Harness {
        let (tx, rx) = sync_channel::<Job>(8);
        Harness {
            tx,
            rx: Arc::new(Mutex::new(rx)),
            metrics: Arc::new(Mutex::new(ServiceMetrics::default())),
            inflight: Arc::new(AtomicUsize::new(0)),
            stop: Arc::new(AtomicBool::new(false)),
            admission: Arc::new(AdmissionController::from_config(
                &ServiceConfig::default(),
                7,
            )),
        }
    }

    impl Harness {
        /// Run `worker_loop` on a thread with the given solve function.
        fn spawn(
            &self,
            mut solve: impl FnMut(&Document, Duration, Option<Deadline>, Tier, &'static str) -> Result<Summary>
                + Send
                + 'static,
        ) -> std::thread::JoinHandle<()> {
            let rx = self.rx.clone();
            let metrics = self.metrics.clone();
            let inflight = self.inflight.clone();
            let stop = self.stop.clone();
            let admission = self.admission.clone();
            std::thread::spawn(move || {
                worker_loop(
                    &mut solve,
                    &rx,
                    &metrics,
                    &inflight,
                    &stop,
                    &admission,
                    1,
                    crate::decompose::Strategy::Window,
                )
            })
        }

        /// Enqueue a job; returns its reply receiver.
        fn send(&self, id: &str, deadline: Option<Deadline>) -> Receiver<Result<Summary>> {
            let (otx, orx) = sync_channel(1);
            self.inflight.fetch_add(1, Ordering::Relaxed);
            self.tx
                .send(Job {
                    id: 1,
                    doc: Document::from_text(id, "Some text here. More text follows."),
                    respond: otx,
                    enqueued: Instant::now(),
                    tier: Tier::Interactive,
                    deadline,
                    workload: "",
                })
                .unwrap();
            orx
        }
    }

    #[test]
    fn a_panicking_solve_is_contained_to_its_request() {
        let h = harness();
        let worker = h.spawn(|doc, _, _, _, _| {
            if doc.id == "boom" {
                panic!("solver exploded");
            }
            Err(anyhow::anyhow!("benign failure"))
        });
        let boom = h.send("boom", None);
        let fine = h.send("fine", None);
        let e = boom.recv().unwrap().unwrap_err();
        assert!(e.to_string().contains("panicked"), "{e}");
        // the SAME worker answers the next job: the panic didn't kill it
        let e = fine.recv().unwrap().unwrap_err();
        assert!(e.to_string().contains("benign"), "{e}");
        let m = h.metrics.lock().unwrap();
        assert_eq!(m.overload.worker_panics, 1);
        assert_eq!(m.failed, 2);
        assert_eq!(h.inflight.load(Ordering::Relaxed), 0);
        drop(m);
        drop(h.tx);
        worker.join().unwrap();
    }

    #[test]
    fn a_poisoned_shared_receiver_keeps_serving() {
        let h = harness();
        // poison the receiver mutex the way a crashed sibling would
        let rx = h.rx.clone();
        let _ = std::thread::spawn(move || {
            let _guard = rx.lock().unwrap();
            panic!("die while holding the queue lock");
        })
        .join();
        assert!(h.rx.is_poisoned(), "setup: mutex must be poisoned");
        let worker = h.spawn(|_, _, _, _, _| Err(anyhow::anyhow!("served")));
        let reply = h.send("doc", None);
        let e = reply.recv().unwrap().unwrap_err();
        assert!(e.to_string().contains("served"), "{e}");
        drop(h.tx);
        worker.join().unwrap();
    }

    #[test]
    fn queue_expired_deadlines_never_reach_the_solver() {
        let h = harness();
        let worker = h.spawn(|_, _, _, _, _| panic!("solver must not run"));
        let reply = h.send("late", Some(Deadline::from_ms(0)));
        let e = reply.recv().unwrap().unwrap_err();
        let d = e
            .downcast_ref::<DeadlineExceeded>()
            .expect("typed DeadlineExceeded");
        assert_eq!(d.budget_ms, 0);
        let m = h.metrics.lock().unwrap();
        assert_eq!(m.overload.deadline_exceeded, 1);
        assert_eq!(m.failed, 1);
        // no latency sample for zero-work replies
        assert_eq!(m.queue_hist.count(), 0);
        drop(m);
        drop(h.tx);
        worker.join().unwrap();
    }
}
