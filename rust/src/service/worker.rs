//! Worker pool: each worker owns a full EsPipeline (embedder + solver/COBI
//! device) and drains the shared queue. A single shared receiver behind a
//! mutex gives natural work-stealing load balance without a router thread.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::config::Settings;
use crate::corpus::Document;
use crate::pipeline::{EsPipeline, Summary};

use super::metrics::ServiceMetrics;

/// One queued request.
pub struct Job {
    pub id: u64,
    pub doc: Document,
    pub respond: SyncSender<Result<Summary>>,
    pub enqueued: Instant,
}

pub fn spawn_workers(
    settings: &Settings,
    rx: Receiver<Job>,
    metrics: Arc<Mutex<ServiceMetrics>>,
    inflight: Arc<AtomicUsize>,
    stop: Arc<AtomicBool>,
) -> Result<Vec<std::thread::JoinHandle<()>>> {
    let shared_rx = Arc::new(Mutex::new(rx));
    let mut handles = Vec::new();
    for w in 0..settings.service.workers.max(1) {
        // per-worker pipeline: derived seed keeps workers decorrelated but
        // the fleet reproducible
        let mut cfg = settings.pipeline.clone();
        cfg.seed = cfg.seed.wrapping_add(w as u64 * 0x9E37);
        let mut pipeline = EsPipeline::from_config(&cfg, &settings.cobi, None)?;
        let rx = shared_rx.clone();
        let metrics = metrics.clone();
        let inflight = inflight.clone();
        let stop = stop.clone();
        let max_batch = settings.service.max_batch.max(1);
        handles.push(
            std::thread::Builder::new()
                .name(format!("cobi-worker-{w}"))
                .spawn(move || {
                    worker_loop(
                        &mut pipeline,
                        &rx,
                        &metrics,
                        &inflight,
                        &stop,
                        max_batch,
                    )
                })?,
        );
    }
    Ok(handles)
}

fn worker_loop(
    pipeline: &mut EsPipeline,
    rx: &Arc<Mutex<Receiver<Job>>>,
    metrics: &Arc<Mutex<ServiceMetrics>>,
    inflight: &Arc<AtomicUsize>,
    stop: &Arc<AtomicBool>,
    max_batch: usize,
) {
    loop {
        // pull a batch: one blocking recv, then drain up to max_batch-1
        let mut batch = Vec::with_capacity(max_batch);
        {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(job) => batch.push(job),
                Err(_) => return, // queue closed: drain complete
            }
            while batch.len() < max_batch {
                match guard.try_recv() {
                    Ok(job) => batch.push(job),
                    Err(_) => break,
                }
            }
        } // release the lock before the (long) solves

        for job in batch {
            if stop.load(Ordering::SeqCst) {
                // shutting down: fail fast instead of burning device time
                let _ = job.respond.try_send(Err(anyhow::anyhow!("shutting down")));
                inflight.fetch_sub(1, Ordering::Relaxed);
                continue;
            }
            let queue_wait = job.enqueued.elapsed();
            let t0 = Instant::now();
            let result = pipeline.summarize(&job.doc);
            let solve_time = t0.elapsed();
            {
                let mut m = metrics.lock().unwrap();
                match &result {
                    Ok(_) => m.completed += 1,
                    Err(_) => m.failed += 1,
                }
                m.record_latency(queue_wait, solve_time);
            }
            inflight.fetch_sub(1, Ordering::Relaxed);
            let _ = job.respond.try_send(result);
        }
    }
}
