//! Worker pool: workers drain the shared request queue and run the
//! embed/formulate/quantize/refine stages. Ising solves take one of two
//! routes:
//!
//!   * `Pooled` (default for pool-capable solvers): the worker walks the
//!     document's `sched::SubproblemGraph` and submits every ready
//!     window's refinement batch to the shared `DevicePool`, so solves
//!     from ALL in-flight documents coalesce on the devices. Seeds are
//!     per-document (`sched::doc_seed`), making results independent of
//!     worker assignment and dispatch order.
//!   * `Local` (pool disabled, or brute/exact/random solvers): each
//!     worker owns a full `EsPipeline` + private solver, as before.
//!
//! A single shared receiver behind a mutex gives natural work-stealing
//! load balance without a router thread.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::Settings;
use crate::corpus::Document;
use crate::obs::{ObsShared, Span};
use crate::pipeline::{EsPipeline, Summary};
use crate::resilience::ResilienceShared;
use crate::runtime::ArtifactRuntime;
use crate::sched::{self, PoolHandle};

use super::metrics::ServiceMetrics;

/// One queued request.
pub struct Job {
    /// Request id.
    pub id: u64,
    /// The document to summarize.
    pub doc: Document,
    /// One-shot reply channel.
    pub respond: SyncSender<Result<Summary>>,
    /// Submission time (queue-wait accounting).
    pub enqueued: Instant,
}

/// How workers perform Ising solves.
pub enum SolveRoute {
    /// Worker-private pipeline + solver (seed derived from worker slot).
    Local,
    /// Shared device pool; per-document seeds.
    Pooled(PoolHandle),
}

/// Spawn the worker threads per `settings.service`.
#[allow(clippy::too_many_arguments)]
pub fn spawn_workers(
    settings: &Settings,
    rx: Receiver<Job>,
    metrics: Arc<Mutex<ServiceMetrics>>,
    inflight: Arc<AtomicUsize>,
    stop: Arc<AtomicBool>,
    route: SolveRoute,
    rt: Option<&ArtifactRuntime>,
    resilience: Option<&ResilienceShared>,
    obs: &ObsShared,
) -> Result<Vec<std::thread::JoinHandle<()>>> {
    let shared_rx = Arc::new(Mutex::new(rx));
    let mut handles = Vec::new();
    let pool_handle = match &route {
        SolveRoute::Pooled(h) => Some(h.clone()),
        SolveRoute::Local => None,
    };
    for w in 0..settings.service.workers.max(1) {
        let rx = shared_rx.clone();
        let metrics = metrics.clone();
        let inflight = inflight.clone();
        let stop = stop.clone();
        let max_batch = settings.service.max_batch.max(1);
        let base_cfg = settings.pipeline.clone();

        // per-worker solve function: takes the request's queue wait so
        // the finished trace carries end-to-end latency, not just solve
        let mut solve: Box<dyn FnMut(&Document, Duration) -> Result<Summary> + Send> =
            match &pool_handle {
                Some(handle) => {
                    let handle = handle.clone();
                    let obs = obs.clone();
                    Box::new(move |doc: &Document, queue_wait: Duration| {
                        // seeds keyed to the DOCUMENT: any worker produces
                        // the same bytes for the same (config, doc)
                        let seed = sched::doc_seed(base_cfg.seed, &doc.id);
                        let mut cfg = base_cfg.clone();
                        cfg.seed = seed;
                        let mut client = handle.client(seed);
                        let t0 = Instant::now();
                        let (summary, root) =
                            sched::summarize_with_pool_traced(doc, &cfg, &mut client, &obs)?;
                        obs.finish_request(
                            root,
                            &doc.id,
                            queue_wait.as_secs_f64(),
                            t0.elapsed().as_secs_f64(),
                        );
                        Ok(summary)
                    })
                }
                None => {
                    // per-worker pipeline: derived seed keeps workers
                    // decorrelated but the fleet reproducible. Pipelines
                    // are built HERE (caller's stack), so the borrowed
                    // artifact runtime never crosses into the threads —
                    // executables are Arc-owned by construction time.
                    // The resilience layer / fault model applies to the
                    // local route exactly like the pooled one
                    // (`resilient_pipeline` is the shared decision).
                    let mut cfg = base_cfg.clone();
                    cfg.seed = cfg.seed.wrapping_add(w as u64 * 0x9E37);
                    let mut pipeline = match crate::resilience::resilient_pipeline(
                        settings,
                        &cfg,
                        rt,
                        resilience,
                        Some((obs, crate::obs::Subsystem::Pipeline)),
                    )? {
                        Some(p) => p,
                        None => EsPipeline::from_config(&cfg, &settings.cobi, rt)?,
                    };
                    let obs = obs.clone();
                    let strategy = cfg.strategy;
                    Box::new(move |doc: &Document, queue_wait: Duration| {
                        // the local pipeline is opaque to per-unit spans:
                        // trace at request granularity (route + score)
                        let mut root = obs.start_request(&doc.id);
                        if let Some(r) = root.as_mut() {
                            r.set("route", "local");
                            r.set("strategy", strategy.as_str());
                        }
                        let t0 = Instant::now();
                        let summary = pipeline.summarize(doc)?;
                        if let Some(r) = root.as_mut() {
                            r.push(
                                Span::new("score")
                                    .with("objective", summary.objective)
                                    .with("selected", summary.selected.len())
                                    .with("solves", summary.total_solves),
                            );
                        }
                        obs.finish_request(
                            root,
                            &doc.id,
                            queue_wait.as_secs_f64(),
                            t0.elapsed().as_secs_f64(),
                        );
                        Ok(summary)
                    })
                }
            };

        let strategy = settings.pipeline.strategy;
        handles.push(
            std::thread::Builder::new()
                .name(format!("cobi-worker-{w}"))
                .spawn(move || {
                    worker_loop(
                        &mut *solve,
                        &rx,
                        &metrics,
                        &inflight,
                        &stop,
                        max_batch,
                        strategy,
                    )
                })?,
        );
    }
    Ok(handles)
}

fn worker_loop(
    solve: &mut dyn FnMut(&Document, Duration) -> Result<Summary>,
    rx: &Arc<Mutex<Receiver<Job>>>,
    metrics: &Arc<Mutex<ServiceMetrics>>,
    inflight: &Arc<AtomicUsize>,
    stop: &Arc<AtomicBool>,
    max_batch: usize,
    strategy: crate::decompose::Strategy,
) {
    loop {
        // pull a batch: one blocking recv, then drain up to max_batch-1
        let mut batch = Vec::with_capacity(max_batch);
        {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(job) => batch.push(job),
                Err(_) => return, // queue closed: drain complete
            }
            while batch.len() < max_batch {
                match guard.try_recv() {
                    Ok(job) => batch.push(job),
                    Err(_) => break,
                }
            }
        } // release the lock before the (long) solves

        for job in batch {
            if stop.load(Ordering::SeqCst) {
                // shutting down: fail fast instead of burning device time
                let _ = job.respond.try_send(Err(anyhow::anyhow!("shutting down")));
                inflight.fetch_sub(1, Ordering::Relaxed);
                continue;
            }
            let queue_wait = job.enqueued.elapsed();
            let t0 = Instant::now();
            let result = solve(&job.doc, queue_wait);
            let solve_time = t0.elapsed();
            {
                let mut m = metrics.lock().unwrap();
                match &result {
                    Ok(_) => {
                        m.completed += 1;
                        m.strategies.record(strategy);
                    }
                    Err(_) => m.failed += 1,
                }
                m.record_latency(queue_wait, solve_time);
            }
            inflight.fetch_sub(1, Ordering::Relaxed);
            let _ = job.respond.try_send(result);
        }
    }
}
