//! Overload-safety primitives for the serving layer: request deadlines,
//! the two-tier admission controller, and typed shed/deadline errors.
//!
//! Everything here defaults OFF: with `[service] default_deadline_ms = 0`
//! and `shed_watermark_ms = 0` no request carries a deadline and no
//! request is ever shed, so the defaults-off serving path is
//! byte-identical to every pre-overload release (the determinism pin in
//! `tests/chaos_service.rs` holds the system to it).
//!
//! Policy (DESIGN.md decision #20): **batch sheds first**. When the
//! estimated queue wait crosses the watermark, batch/backfill-tier
//! requests are rejected with a `RETRY <after_ms>` hint; interactive
//! requests keep flowing until [`INTERACTIVE_SHED_FACTOR`] times the
//! watermark, and the bounded job queue itself is the final hard cap for
//! both tiers. Retry hints carry seeded jitter drawn from a dedicated
//! RNG stream so a thundering herd of shed clients decorrelates — and a
//! test can still replay the exact hint sequence.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::config::ServiceConfig;
use crate::util::rng::Pcg32;

/// RNG stream id for the seeded retry-after jitter (audited for
/// uniqueness by `util::rng::rng_stream_ids_are_pairwise_distinct`).
pub(crate) const RETRY_JITTER_STREAM: u64 = 0x4E77_12A1;

/// Interactive requests are shed only when the estimated queue wait
/// exceeds `INTERACTIVE_SHED_FACTOR *` the configured watermark — the
/// "shed batch first, interactive last" policy knob.
pub const INTERACTIVE_SHED_FACTOR: u64 = 4;

/// Request priority tier for admission control. Interactive is the
/// default and the last to be shed; batch/backfill traffic (tagged with
/// the TCP `::BATCH::` header) sheds first under pressure.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Tier {
    /// Latency-sensitive foreground traffic (default).
    #[default]
    Interactive,
    /// Backfill / bulk traffic: first to shed under pressure.
    Batch,
}

impl Tier {
    /// Stable lowercase label (metrics, span attributes, errors).
    pub fn as_str(self) -> &'static str {
        match self {
            Tier::Interactive => "interactive",
            Tier::Batch => "batch",
        }
    }
}

impl fmt::Display for Tier {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// An absolute per-request deadline plus the budget it was derived from
/// (kept so the typed error can report what the client asked for).
#[derive(Clone, Copy, Debug)]
pub struct Deadline {
    at: Instant,
    budget_ms: u64,
}

impl Deadline {
    /// Deadline `budget_ms` milliseconds from now. A zero budget is
    /// already expired — useful for "reject unless immediate" probes.
    pub fn from_ms(budget_ms: u64) -> Self {
        Self {
            at: Instant::now() + Duration::from_millis(budget_ms),
            budget_ms,
        }
    }

    /// The originally requested budget in milliseconds.
    pub fn budget_ms(&self) -> u64 {
        self.budget_ms
    }

    /// Has the deadline passed?
    pub fn expired(&self) -> bool {
        Instant::now() >= self.at
    }

    /// The typed error a stage returns instead of working past the
    /// deadline.
    pub fn exceeded(&self) -> DeadlineExceeded {
        DeadlineExceeded {
            budget_ms: self.budget_ms,
        }
    }
}

/// Typed error for a request whose deadline passed before (or during)
/// solving. Workers check before the solve, the pooled executor before
/// every pipeline stage, and pool devices before dispatch — so a dead
/// request never burns device time.
#[derive(Debug, thiserror::Error)]
#[error("deadline exceeded (budget {budget_ms} ms)")]
pub struct DeadlineExceeded {
    /// The request's deadline budget in milliseconds.
    pub budget_ms: u64,
}

/// Typed error for a request rejected by admission control (or the hard
/// queue cap while shedding is enabled). The TCP layer renders it as
/// `ERR RETRY <after_ms>`.
#[derive(Debug, thiserror::Error)]
#[error("overloaded ({tier}): retry after {retry_after_ms} ms")]
pub struct Shed {
    /// Tier of the rejected request.
    pub tier: Tier,
    /// Client backoff hint in milliseconds (watermark base + seeded
    /// jitter).
    pub retry_after_ms: u64,
}

/// Watermark-based two-tier admission controller.
///
/// Queue wait is estimated with Little's law over live counters:
/// `inflight * ema(solve time) / workers`. The estimate feeds from the
/// workers' measured solve times (EMA, α = 1/8), so it needs one
/// completed request to warm up — a cold service admits everything,
/// which is the safe direction.
pub struct AdmissionController {
    watermark_ms: u64,
    ema_solve_us: AtomicU64,
    jitter: Mutex<Pcg32>,
}

impl AdmissionController {
    /// Controller from `[service]` settings; `seed` keys the jitter
    /// stream (the pipeline master seed, so hint sequences replay).
    pub fn from_config(cfg: &ServiceConfig, seed: u64) -> Self {
        Self {
            watermark_ms: cfg.shed_watermark_ms,
            ema_solve_us: AtomicU64::new(0),
            jitter: Mutex::new(Pcg32::new(seed, RETRY_JITTER_STREAM)),
        }
    }

    /// Is shedding configured at all (watermark > 0)?
    pub fn enabled(&self) -> bool {
        self.watermark_ms > 0
    }

    /// Feed one measured solve time into the wait estimator.
    pub fn observe_solve(&self, took: Duration) {
        let us = took.as_micros().min(u128::from(u64::MAX)) as u64;
        // racy EMA is fine: this is an advisory load signal, not a metric
        let prev = self.ema_solve_us.load(Ordering::Relaxed);
        let next = if prev == 0 { us } else { (prev * 7 + us) / 8 };
        self.ema_solve_us.store(next, Ordering::Relaxed);
    }

    /// Estimated queue wait in milliseconds for a request arriving now.
    pub fn estimated_wait_ms(&self, inflight: usize, workers: usize) -> u64 {
        let ema_us = self.ema_solve_us.load(Ordering::Relaxed);
        (inflight as u64).saturating_mul(ema_us) / (workers.max(1) as u64) / 1_000
    }

    /// Admit or shed one request. Batch tier sheds past the watermark;
    /// interactive holds out to [`INTERACTIVE_SHED_FACTOR`]× it.
    pub fn admit(&self, tier: Tier, inflight: usize, workers: usize) -> Result<(), Shed> {
        if !self.enabled() {
            return Ok(());
        }
        let est = self.estimated_wait_ms(inflight, workers);
        let limit = match tier {
            Tier::Batch => self.watermark_ms,
            Tier::Interactive => self.watermark_ms.saturating_mul(INTERACTIVE_SHED_FACTOR),
        };
        if est > limit {
            Err(self.shed(tier))
        } else {
            Ok(())
        }
    }

    /// Build the typed shed error with the next backoff hint.
    pub fn shed(&self, tier: Tier) -> Shed {
        Shed {
            tier,
            retry_after_ms: self.retry_after_ms(),
        }
    }

    /// Next backoff hint: watermark base (min 25 ms) plus one seeded
    /// jitter draw in `[0, base)` — deterministic sequence per service.
    pub fn retry_after_ms(&self) -> u64 {
        let base = self.watermark_ms.max(25);
        let mut rng = self
            .jitter
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        base + rng.next_u64() % base
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn controller(watermark_ms: u64) -> AdmissionController {
        let cfg = ServiceConfig {
            shed_watermark_ms: watermark_ms,
            ..Default::default()
        };
        AdmissionController::from_config(&cfg, 0xC0B1)
    }

    #[test]
    fn zero_budget_deadline_is_immediately_expired() {
        let d = Deadline::from_ms(0);
        assert!(d.expired());
        assert_eq!(d.exceeded().budget_ms, 0);
        // a generous budget is not expired at birth
        assert!(!Deadline::from_ms(60_000).expired());
    }

    #[test]
    fn disabled_controller_admits_everything() {
        let c = controller(0);
        assert!(!c.enabled());
        c.observe_solve(Duration::from_millis(500));
        assert!(c.admit(Tier::Batch, 10_000, 1).is_ok());
        assert!(c.admit(Tier::Interactive, 10_000, 1).is_ok());
    }

    #[test]
    fn cold_controller_admits_until_the_estimator_warms() {
        // no observed solves yet -> estimate 0 -> admit both tiers
        let c = controller(10);
        assert!(c.admit(Tier::Batch, 64, 1).is_ok());
        assert!(c.admit(Tier::Interactive, 64, 1).is_ok());
    }

    #[test]
    fn batch_sheds_first_interactive_last() {
        let c = controller(10);
        c.observe_solve(Duration::from_millis(20));
        // est = 1 * 20ms / 1 = 20ms: past the batch watermark (10),
        // under the interactive limit (40)
        let shed = c.admit(Tier::Batch, 1, 1).unwrap_err();
        assert_eq!(shed.tier, Tier::Batch);
        assert!(shed.retry_after_ms >= 25);
        assert!(c.admit(Tier::Interactive, 1, 1).is_ok());
        // est = 3 * 20ms = 60ms: past both limits
        assert!(c.admit(Tier::Interactive, 3, 1).is_err());
        // more workers divide the estimate back under the limits
        assert!(c.admit(Tier::Batch, 1, 4).is_ok());
    }

    #[test]
    fn retry_hints_are_seeded_and_bounded() {
        let a = controller(40);
        let b = controller(40);
        let hints: Vec<u64> = (0..16).map(|_| a.retry_after_ms()).collect();
        let replay: Vec<u64> = (0..16).map(|_| b.retry_after_ms()).collect();
        assert_eq!(hints, replay, "hint sequence must replay from the seed");
        assert!(hints.iter().all(|&h| (40..80).contains(&h)), "{hints:?}");
        assert!(
            hints.windows(2).any(|w| w[0] != w[1]),
            "jitter must actually vary: {hints:?}"
        );
    }

    #[test]
    fn error_displays_are_protocol_stable() {
        let d = DeadlineExceeded { budget_ms: 250 };
        assert_eq!(d.to_string(), "deadline exceeded (budget 250 ms)");
        let s = Shed {
            tier: Tier::Batch,
            retry_after_ms: 60,
        };
        assert_eq!(s.to_string(), "overloaded (batch): retry after 60 ms");
        assert_eq!(Tier::default(), Tier::Interactive);
    }
}
