//! Random-selection baseline (paper §IV-A): pick M sentences uniformly at
//! random per iteration, keep the best under the FP objective. The
//! reference point that any Ising machinery must beat.

use crate::ising::EsProblem;
use crate::util::rng::Pcg32;

use super::SelectionResult;

/// Uniform-random M-subset baseline.
pub struct RandomBaseline {
    rng: Pcg32,
}

impl RandomBaseline {
    /// Baseline with a seeded RNG stream.
    pub fn seeded(seed: u64) -> Self {
        Self {
            rng: Pcg32::new(seed, 0xBA5E),
        }
    }

    /// One random M-subset.
    pub fn sample(&mut self, p: &EsProblem) -> SelectionResult {
        let mut selected = self.rng.sample_indices(p.n(), p.m);
        selected.sort_unstable();
        SelectionResult {
            objective: p.objective(&selected),
            selected,
        }
    }

    /// Best of `iterations` random selections (the paper's "Number of
    /// iterations" axis for the baseline).
    pub fn best_of(&mut self, p: &EsProblem, iterations: usize) -> SelectionResult {
        let mut best = self.sample(p);
        for _ in 1..iterations.max(1) {
            let cand = self.sample(p);
            if cand.objective > best.objective {
                best = cand;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform_es(n: usize, m: usize) -> EsProblem {
        // distinct mu so optima are unique
        let mu: Vec<f32> = (0..n).map(|i| 0.3 + 0.01 * i as f32).collect();
        EsProblem {
            mu,
            beta: vec![0.0; n * n],
            lambda: 0.6,
            m,
        }
    }

    #[test]
    fn sample_is_valid_subset() {
        let p = uniform_es(20, 6);
        let mut b = RandomBaseline::seeded(1);
        for _ in 0..50 {
            let r = b.sample(&p);
            assert_eq!(r.selected.len(), 6);
            let mut d = r.selected.clone();
            d.dedup();
            assert_eq!(d.len(), 6);
            assert!(r.selected.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn best_of_is_monotone_in_iterations() {
        let p = uniform_es(20, 6);
        // same seed: the k-iteration best is a prefix-max of the sequence
        let a = RandomBaseline::seeded(3).best_of(&p, 5).objective;
        let b = RandomBaseline::seeded(3).best_of(&p, 50).objective;
        assert!(b >= a);
    }

    #[test]
    fn many_iterations_approach_optimum_on_trivial_instance() {
        let p = uniform_es(10, 2);
        // optimum = two largest mu
        let best = p.objective(&[8, 9]);
        let got = RandomBaseline::seeded(9).best_of(&p, 500).objective;
        assert!((got - best).abs() < 1e-9, "got {got} want {best}");
    }
}
