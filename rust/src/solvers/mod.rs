//! Solver suite: the COBI-simulating oscillator solver plus every baseline
//! the paper evaluates against (Tabu, brute force, random, exact/Gurobi
//! substitute) and one extension (simulated annealing).

pub mod brute;
pub mod exact;
pub mod greedy;
pub mod oscillator;
pub mod random;
pub mod sa;
pub mod tabu;

use crate::ising::Ising;

/// Result of one unconstrained Ising solve.
#[derive(Debug, Clone)]
pub struct SolveResult {
    /// Spin configuration in {-1, +1}.
    pub spins: Vec<i8>,
    /// Ising energy of `spins` under the SOLVED (possibly quantized)
    /// instance. Callers re-score under the FP objective themselves.
    pub energy: f64,
}

/// Result of one constrained (cardinality-M) selection solve.
#[derive(Debug, Clone)]
pub struct SelectionResult {
    pub selected: Vec<usize>,
    /// Eq. 3 objective (to maximize) of `selected`.
    pub objective: f64,
}

/// An Ising minimizer. Implementations are deterministic given their
/// construction seed, so experiments replay exactly.
pub trait IsingSolver {
    fn name(&self) -> &'static str;

    /// Minimize H over spin configurations.
    fn solve(&mut self, ising: &Ising) -> SolveResult;

    /// Solve several independent instances. The default solves them
    /// sequentially; devices with a batched dispatch path (the COBI HLO
    /// backend's `anneal_batch` artifact) override it to amortize
    /// per-call overhead — the refinement loop always goes through here.
    fn solve_batch(&mut self, instances: &[&Ising]) -> Vec<SolveResult> {
        instances.iter().map(|i| self.solve(i)).collect()
    }
}

/// Helper shared by solvers: energy + local-field initialisation.
pub(crate) fn init_local_fields(ising: &Ising, s: &[i8]) -> Vec<f64> {
    let n = ising.n;
    let mut l = vec![0.0f64; n];
    for i in 0..n {
        let row = &ising.j[i * n..(i + 1) * n];
        let mut acc = 0.0f64;
        for j in 0..n {
            acc += row[j] as f64 * s[j] as f64;
        }
        l[i] = ising.h[i] as f64 + 2.0 * acc;
    }
    l
}

/// Apply a flip of spin `k` and update local fields incrementally:
/// L_i += 4 J_ik s_k(new) for all i != k. O(n).
#[inline]
pub(crate) fn apply_flip(ising: &Ising, s: &mut [i8], l: &mut [f64], k: usize) {
    s[k] = -s[k];
    let new_sk = s[k] as f64;
    let n = ising.n;
    let row = &ising.j[k * n..(k + 1) * n];
    for i in 0..n {
        // row[k] == 0 (zero diagonal) so including i == k is harmless
        l[i] += 4.0 * row[i] as f64 * new_sk;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn random_ising(rng: &mut Pcg32, n: usize) -> Ising {
        let mut ising = Ising::new(n);
        for i in 0..n {
            ising.h[i] = rng.range_f32(-2.0, 2.0);
            for j in (i + 1)..n {
                ising.set_pair(i, j, rng.range_f32(-1.0, 1.0));
            }
        }
        ising
    }

    #[test]
    fn incremental_local_fields_track_exact() {
        let mut rng = Pcg32::seeded(77);
        let ising = random_ising(&mut rng, 16);
        let mut s: Vec<i8> = (0..16).map(|_| if rng.bernoulli(0.5) { 1 } else { -1 }).collect();
        let mut l = init_local_fields(&ising, &s);
        for _ in 0..50 {
            let k = rng.below(16) as usize;
            apply_flip(&ising, &mut s, &mut l, k);
            // recompute from scratch and compare
            let fresh = init_local_fields(&ising, &s);
            for i in 0..16 {
                assert!((l[i] - fresh[i]).abs() < 1e-9, "i={i}");
            }
        }
    }

    #[test]
    fn flip_energy_identity() {
        // E(after flip k) - E(before) == -2 s_k L_k
        let mut rng = Pcg32::seeded(78);
        let ising = random_ising(&mut rng, 12);
        let mut s: Vec<i8> = (0..12).map(|_| if rng.bernoulli(0.5) { 1 } else { -1 }).collect();
        let mut l = init_local_fields(&ising, &s);
        for _ in 0..20 {
            let k = rng.below(12) as usize;
            let e0 = ising.energy(&s);
            let pred = -2.0 * s[k] as f64 * l[k];
            apply_flip(&ising, &mut s, &mut l, k);
            let e1 = ising.energy(&s);
            assert!(((e1 - e0) - pred).abs() < 1e-9);
        }
    }
}
