//! Solver suite: the COBI-simulating oscillator solver plus every baseline
//! the paper evaluates against (Tabu, brute force, random, exact/Gurobi
//! substitute) and two extensions (simulated annealing, and the
//! Snowball-style sharded parallel-spin MCMC solver).

pub mod brute;
pub mod exact;
pub mod greedy;
pub mod kernel;
pub mod oscillator;
pub mod random;
pub mod sa;
pub mod snowball;
pub mod tabu;

pub use kernel::{KernelScratch, QuantSolve, SolveScratch, SolverKernel};

use crate::ising::Ising;

/// Result of one unconstrained Ising solve.
#[derive(Debug, Clone)]
pub struct SolveResult {
    /// Spin configuration in {-1, +1}.
    pub spins: Vec<i8>,
    /// Ising energy of `spins` under the SOLVED (possibly quantized)
    /// instance. Callers re-score under the FP objective themselves.
    pub energy: f64,
}

/// Result of one constrained (cardinality-M) selection solve.
#[derive(Debug, Clone)]
pub struct SelectionResult {
    /// Chosen indices, ascending.
    pub selected: Vec<usize>,
    /// Eq. 3 objective (to maximize) of `selected`.
    pub objective: f64,
}

/// Tolerance under which two energies (or move deltas) count as exactly
/// tied for the solver-wide tie-break rule (see [`IsingSolver`]) on the
/// `f64` kernel path. The integer kernel path ([`SolverKernel`] over
/// [`QuantIsing`](crate::ising::QuantIsing)) has no epsilon: ties are
/// exact integer equality, which agrees with this rule bit-for-bit on
/// quantized instances (see `ising::quant_model` module docs).
pub const TIE_EPS: f64 = 1e-12;

/// An Ising minimizer. Implementations are deterministic given their
/// construction seed, so experiments replay exactly.
///
/// ## Tie-break rule
///
/// Wherever an implementation selects among exactly tied candidates
/// (move deltas within [`TIE_EPS`], equal-energy configurations), the
/// **lowest spin index / earliest candidate wins**: argmin/argmax scans
/// replace the incumbent only on strict improvement, and best-so-far
/// tracking keeps the earlier result on ties. This is what lets the
/// solver portfolio route requests without changing summaries under a
/// static policy — a solver that resolved ties by scan direction or
/// insertion order would silently diverge between backends. (The COBI
/// readout obeys the same spirit: an exactly-zero oscillator phase maps
/// to spin +1, identically in the native and HLO backends.)
///
/// # Examples
///
/// ```
/// use cobi_es::ising::Ising;
/// use cobi_es::solvers::{tabu::TabuSolver, IsingSolver};
///
/// let mut ising = Ising::new(4);
/// ising.set_pair(0, 1, -1.0); // ferromagnetic pair
/// let mut solver = TabuSolver::seeded(7);
/// let r = solver.solve(&ising);
/// assert_eq!(r.spins[0], r.spins[1]); // aligned in the ground state
/// assert!((ising.energy(&r.spins) - r.energy).abs() < 1e-9);
/// ```
pub trait IsingSolver {
    /// Stable solver name for reports and routing.
    fn name(&self) -> &'static str;

    /// Minimize H over spin configurations.
    fn solve(&mut self, ising: &Ising) -> SolveResult;

    /// Solve from a warm-start hint: `init` is a full spin configuration
    /// (length `ising.n`) believed to be near a good solution — typically
    /// a cached solution of a structurally similar instance
    /// (`portfolio::WarmStartCache`). The default ignores the hint and
    /// delegates to [`solve`](IsingSolver::solve); hint-capable solvers
    /// (Tabu, SA, greedy descent) start their first descent/restart from
    /// `init` instead of a random configuration. A correct
    /// implementation never returns a result worse than `init` itself.
    ///
    /// # Examples
    ///
    /// ```
    /// use cobi_es::ising::Ising;
    /// use cobi_es::solvers::{greedy::GreedyDescent, IsingSolver};
    ///
    /// let mut ising = Ising::new(2);
    /// ising.set_pair(0, 1, -1.0);
    /// // both flips tie from (+1, -1); lowest index wins: spin 0 flips
    /// let r = GreedyDescent::new().solve_from(&ising, &[1, -1]);
    /// assert_eq!(r.spins, vec![-1, -1]);
    /// ```
    fn solve_from(&mut self, ising: &Ising, init: &[i8]) -> SolveResult {
        debug_assert_eq!(init.len(), ising.n, "warm-start hint length mismatch");
        self.solve(ising)
    }

    /// Solve several independent instances.
    ///
    /// ## Batching contract
    ///
    /// Exactly one result per instance, in input order, and every result
    /// must be identical to what the same solver would have produced by
    /// calling [`solve`](IsingSolver::solve) on the instances one at a
    /// time, in order — batching may amortize dispatch cost but must not
    /// change results (for stochastic solvers that means consuming the
    /// RNG stream in instance order). The default solves sequentially;
    /// devices with a batched dispatch path (the COBI HLO backend's
    /// `anneal_batch` artifact) override it to amortize per-call
    /// overhead — the refinement loop always goes through here.
    ///
    /// # Examples
    ///
    /// ```
    /// use cobi_es::ising::Ising;
    /// use cobi_es::solvers::{tabu::TabuSolver, IsingSolver};
    ///
    /// let mut a = Ising::new(3);
    /// a.h[0] = 1.0;
    /// let mut b = Ising::new(3);
    /// b.h[2] = -1.0;
    /// let batched = TabuSolver::seeded(5).solve_batch(&[&a, &b]);
    /// // identical to sequential solves on a same-seeded solver
    /// let mut seq = TabuSolver::seeded(5);
    /// assert_eq!(batched[0].spins, seq.solve(&a).spins);
    /// assert_eq!(batched[1].spins, seq.solve(&b).spins);
    /// ```
    fn solve_batch(&mut self, instances: &[&Ising]) -> Vec<SolveResult> {
        instances.iter().map(|i| self.solve(i)).collect()
    }

    /// The integer-domain entry of this solver, if it has one. Hint-free
    /// heuristics with a [`SolverKernel`] inner loop (Tabu, SA, greedy
    /// descent) return `Some(self)`; devices and facades return `None`
    /// (the default) and keep the `f32` batch path. The refinement fast
    /// path uses this to quantize straight into integer buffers and skip
    /// the `f32` instance materialization entirely — results are
    /// bit-identical either way (see `ising::quant_model`).
    fn quant_kernel(&mut self) -> Option<&mut dyn QuantSolve> {
        None
    }
}

/// Apply a flip of spin `k` and update local fields incrementally:
/// L_i += 4 J_ik s_k(new) for all i != k. O(n).
#[inline]
pub(crate) fn apply_flip(ising: &Ising, s: &mut [i8], l: &mut [f64], k: usize) {
    s[k] = -s[k];
    let new_sk = s[k] as f64;
    let n = ising.n;
    let row = &ising.j[k * n..(k + 1) * n];
    for i in 0..n {
        // row[k] == 0 (zero diagonal) so including i == k is harmless
        l[i] += 4.0 * row[i] as f64 * new_sk;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn random_ising(rng: &mut Pcg32, n: usize) -> Ising {
        let mut ising = Ising::new(n);
        for i in 0..n {
            ising.h[i] = rng.range_f32(-2.0, 2.0);
            for j in (i + 1)..n {
                ising.set_pair(i, j, rng.range_f32(-1.0, 1.0));
            }
        }
        ising
    }

    #[test]
    fn incremental_local_fields_track_exact() {
        let mut rng = Pcg32::seeded(77);
        let ising = random_ising(&mut rng, 16);
        let mut s: Vec<i8> = (0..16).map(|_| if rng.bernoulli(0.5) { 1 } else { -1 }).collect();
        let mut l = vec![0.0f64; 16];
        ising.local_fields_into(&s, &mut l);
        for _ in 0..50 {
            let k = rng.below(16) as usize;
            apply_flip(&ising, &mut s, &mut l, k);
            // recompute from scratch and compare
            let mut fresh = vec![0.0f64; 16];
            ising.local_fields_into(&s, &mut fresh);
            for i in 0..16 {
                assert!((l[i] - fresh[i]).abs() < 1e-9, "i={i}");
            }
        }
    }

    #[test]
    fn warm_started_solvers_never_lose_a_supplied_ground_state() {
        // unique ground state: h = [1, -1, 1], no couplings -> [-1, 1, -1].
        // A warm start AT the ground state must come back unchanged from
        // every hint-capable solver (best-so-far keeps the earlier result
        // on ties, and nothing beats the ground state strictly).
        let mut ising = Ising::new(3);
        ising.h = vec![1.0, -1.0, 1.0];
        let ground = vec![-1i8, 1, -1];
        let results = [
            crate::solvers::tabu::TabuSolver::seeded(3).solve_from(&ising, &ground),
            crate::solvers::sa::SaSolver::seeded(3).solve_from(&ising, &ground),
            crate::solvers::greedy::GreedyDescent::new().solve_from(&ising, &ground),
        ];
        for r in results {
            assert_eq!(r.spins, ground);
            assert!((r.energy + 3.0).abs() < 1e-9);
        }
    }

    #[test]
    fn tied_flips_resolve_to_the_lowest_index() {
        // 2-spin ferromagnet probed from (+1, -1): flipping either spin
        // gains exactly the same energy. The documented tie-break rule
        // (lowest index wins) means spin 0 flips, landing in (-1, -1) —
        // never (+1, +1), which a highest-index scan would produce.
        let mut ising = Ising::new(2);
        ising.set_pair(0, 1, -1.0);
        let g = crate::solvers::greedy::GreedyDescent::new().solve_from(&ising, &[1, -1]);
        assert_eq!(g.spins, vec![-1, -1]);
        let mut tabu = crate::solvers::tabu::TabuSolver::new(
            1,
            crate::solvers::tabu::TabuConfig {
                restarts: 1,
                ..Default::default()
            },
        );
        let t = tabu.solve_from(&ising, &[1, -1]);
        assert_eq!(t.spins, vec![-1, -1]);
    }

    #[test]
    fn flip_energy_identity() {
        // E(after flip k) - E(before) == -2 s_k L_k
        let mut rng = Pcg32::seeded(78);
        let ising = random_ising(&mut rng, 12);
        let mut s: Vec<i8> = (0..12).map(|_| if rng.bernoulli(0.5) { 1 } else { -1 }).collect();
        let mut l = vec![0.0f64; 12];
        ising.local_fields_into(&s, &mut l);
        for _ in 0..20 {
            let k = rng.below(12) as usize;
            let e0 = ising.energy(&s);
            let pred = <Ising as SolverKernel>::flip_delta(&s, &l, k);
            apply_flip(&ising, &mut s, &mut l, k);
            let e1 = ising.energy(&s);
            assert!(((e1 - e0) - pred).abs() < 1e-9);
        }
    }
}
