//! Simulated annealing Ising solver (extension beyond the paper's
//! baselines; used in the ablation benches as a second software reference
//! point and by tests as an independent heuristic cross-check).
//!
//! The sweep loop is generic over [`SolverKernel`]: integer-valued
//! instances run on `i64` accumulators (only the Metropolis exponent
//! touches floating point, computed from the exact integer delta), others
//! on the original `f64` path — bit-identical on quantized instances,
//! pinned by the equivalence test below.

use crate::ising::{Ising, QuantIsing};
use crate::util::rng::Pcg32;

use super::kernel::{KernelScratch, QuantSolve, SolveScratch, SolverKernel};
use super::{IsingSolver, SolveResult};

/// Simulated-annealing schedule parameters.
#[derive(Debug, Clone)]
pub struct SaConfig {
    /// Sweeps (n flip attempts each).
    pub sweeps: usize,
    /// Initial/final temperatures for geometric cooling.
    pub t_start: f64,
    /// Final temperature of the geometric cooling.
    pub t_end: f64,
    /// Independent restarts.
    pub restarts: usize,
}

impl Default for SaConfig {
    fn default() -> Self {
        Self {
            sweeps: 300,
            t_start: 4.0,
            t_end: 0.05,
            restarts: 2,
        }
    }
}

/// Simulated annealing over Ising instances (geometric cooling).
pub struct SaSolver {
    cfg: SaConfig,
    rng: Pcg32,
    scratch: SolveScratch,
}

impl SaSolver {
    /// Solver with an explicit schedule.
    pub fn new(seed: u64, cfg: SaConfig) -> Self {
        Self {
            cfg,
            rng: Pcg32::new(seed, 0x5A5A),
            scratch: SolveScratch::default(),
        }
    }

    /// Solver with the default schedule, seeded.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, SaConfig::default())
    }

    /// Reset the RNG to a fresh stream keyed by `seed` (see
    /// `TabuSolver::reseed`; the device pool re-seeds per request).
    pub fn reseed(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed, 0x5A5A);
    }

    /// Solve, picking the coefficient domain (see `TabuSolver::solve_any`).
    fn solve_any(&mut self, ising: &Ising, warm: Option<&[i8]>) -> SolveResult {
        let Self { cfg, rng, scratch } = self;
        if scratch.quant.try_copy_from(ising) {
            let energy = sa_core(&scratch.quant, cfg, rng, &mut scratch.int, warm);
            SolveResult {
                spins: scratch.int.best.clone(),
                energy,
            }
        } else {
            let energy = sa_core(ising, cfg, rng, &mut scratch.fp, warm);
            SolveResult {
                spins: scratch.fp.best.clone(),
                energy,
            }
        }
    }

    /// Force the `f64` kernel — the reference entry the integer path is
    /// pinned against (see `TabuSolver::solve_reference_f64`).
    pub fn solve_reference_f64(&mut self, ising: &Ising) -> SolveResult {
        let Self { cfg, rng, scratch } = self;
        let energy = sa_core(ising, cfg, rng, &mut scratch.fp, None);
        SolveResult {
            spins: scratch.fp.best.clone(),
            energy,
        }
    }
}

/// Restart wrapper over [`sa_run`]: restart 0 starts from `warm` when
/// given (no init randomness; best-so-far starts at the hint, so the
/// result is never worse than it), later restarts from random
/// configurations; best kept on strict `<`.
pub(crate) fn sa_core<K: SolverKernel>(
    k: &K,
    cfg: &SaConfig,
    rng: &mut Pcg32,
    ks: &mut KernelScratch<K::Acc>,
    warm: Option<&[i8]>,
) -> f64 {
    let n = k.n();
    debug_assert!(warm.map_or(true, |h| h.len() == n), "warm-start hint length mismatch");
    ks.prepare(n);
    let mut overall: Option<K::Acc> = None;
    for r in 0..cfg.restarts.max(1) {
        match warm {
            Some(h) if r == 0 => ks.spins.copy_from_slice(h),
            _ => {
                for x in ks.spins.iter_mut() {
                    *x = if rng.bernoulli(0.5) { 1 } else { -1 };
                }
            }
        }
        let e = sa_run(k, cfg, rng, ks);
        if overall.map_or(true, |b| e < b) {
            overall = Some(e);
            ks.best.copy_from_slice(&ks.run_best);
        }
    }
    K::to_f64(overall.expect("restarts >= 1"))
}

/// One annealing run from the configuration in `ks.spins`; best spins of
/// the run land in `ks.run_best`.
fn sa_run<K: SolverKernel>(
    k: &K,
    cfg: &SaConfig,
    rng: &mut Pcg32,
    ks: &mut KernelScratch<K::Acc>,
) -> K::Acc {
    let n = k.n();
    k.local_fields_into(&ks.spins, &mut ks.l);
    let mut e = k.energy_acc(&ks.spins);
    let mut best_e = e;
    ks.run_best.copy_from_slice(&ks.spins);

    let sweeps = cfg.sweeps.max(1);
    let cool = (cfg.t_end / cfg.t_start).powf(1.0 / sweeps as f64);
    let mut t = cfg.t_start;
    for _ in 0..sweeps {
        for _ in 0..n {
            let i = rng.below(n as u32) as usize;
            let delta = K::flip_delta(&ks.spins, &ks.l, i);
            // downhill-or-flat accepts free (no RNG draw — identical
            // draw order across domains); uphill via Metropolis on the
            // exact delta
            if K::non_increasing(delta) || rng.f64() < (-K::to_f64(delta) / t).exp() {
                k.apply_flip_acc(&mut ks.spins, &mut ks.l, i);
                e += delta;
                if K::lt_margin(e, best_e) {
                    best_e = e;
                    ks.run_best.copy_from_slice(&ks.spins);
                }
            }
        }
        t *= cool;
    }
    best_e
}

impl IsingSolver for SaSolver {
    fn name(&self) -> &'static str {
        "sa"
    }

    fn solve(&mut self, ising: &Ising) -> SolveResult {
        self.solve_any(ising, None)
    }

    fn solve_from(&mut self, ising: &Ising, init: &[i8]) -> SolveResult {
        debug_assert_eq!(init.len(), ising.n, "warm-start hint length mismatch");
        // first restart from the hint, remaining restarts cold; strict
        // `<` keeps the warm result on exact ties
        self.solve_any(ising, Some(init))
    }

    fn quant_kernel(&mut self) -> Option<&mut dyn QuantSolve> {
        Some(self)
    }
}

impl QuantSolve for SaSolver {
    fn solve_quant_into(&mut self, q: &QuantIsing, out: &mut Vec<i8>) -> f64 {
        let Self { cfg, rng, scratch } = self;
        let energy = sa_core(q, cfg, rng, &mut scratch.int, None);
        out.clear();
        out.extend_from_slice(&scratch.int.best);
        energy
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cobi::testutil::quantized_glass;
    use crate::solvers::exact::ising_ground_exhaustive;

    fn random_ising(seed: u64, n: usize) -> Ising {
        let mut rng = Pcg32::seeded(seed);
        let mut ising = Ising::new(n);
        for i in 0..n {
            ising.h[i] = rng.range_f32(-1.5, 1.5);
            for j in (i + 1)..n {
                ising.set_pair(i, j, rng.range_f32(-1.0, 1.0));
            }
        }
        ising
    }

    #[test]
    fn finds_ground_state_on_small_instances() {
        for seed in 0..4 {
            let ising = random_ising(seed, 12);
            let (ge, _, _) = ising_ground_exhaustive(&ising);
            let r = SaSolver::seeded(seed + 10).solve(&ising);
            assert!(
                (r.energy - ge).abs() < 1e-6,
                "seed {seed}: sa {} vs ground {ge}",
                r.energy
            );
        }
    }

    #[test]
    fn reported_energy_matches_spins() {
        let ising = random_ising(7, 24);
        let r = SaSolver::seeded(2).solve(&ising);
        assert!((ising.energy(&r.spins) - r.energy).abs() < 1e-6);
    }

    #[test]
    fn deterministic_given_seed() {
        let ising = random_ising(8, 16);
        assert_eq!(
            SaSolver::seeded(4).solve(&ising).spins,
            SaSolver::seeded(4).solve(&ising).spins
        );
    }

    #[test]
    fn integer_kernel_is_bit_identical_to_f64_on_quantized_instances() {
        // acceptance pin (SA): identical spins, bitwise-equal energy —
        // including identical Metropolis draw order, since the free-accept
        // branch decides the same way in both domains
        for seed in 0..6 {
            for n in [5, 12, 20, 33] {
                let inst = quantized_glass(2000 + seed, n);
                let a = SaSolver::seeded(seed).solve_reference_f64(&inst);
                let b = SaSolver::seeded(seed).solve(&inst);
                assert_eq!(a.spins, b.spins, "seed {seed} n {n}");
                assert_eq!(a.energy.to_bits(), b.energy.to_bits(), "seed {seed} n {n}");
            }
        }
    }

    #[test]
    fn integer_warm_start_never_loses_the_hint() {
        let inst = quantized_glass(91, 12);
        let (ge, gs, _) = ising_ground_exhaustive(&inst);
        let r = SaSolver::seeded(3).solve_from(&inst, &gs);
        assert_eq!(r.spins, gs);
        assert!((r.energy - ge).abs() < 1e-9);
    }
}
