//! Simulated annealing Ising solver (extension beyond the paper's
//! baselines; used in the ablation benches as a second software reference
//! point and by tests as an independent heuristic cross-check).

use crate::ising::Ising;
use crate::util::rng::Pcg32;

use super::{apply_flip, init_local_fields, IsingSolver, SolveResult};

#[derive(Debug, Clone)]
pub struct SaConfig {
    /// Sweeps (n flip attempts each).
    pub sweeps: usize,
    /// Initial/final temperatures for geometric cooling.
    pub t_start: f64,
    pub t_end: f64,
    /// Independent restarts.
    pub restarts: usize,
}

impl Default for SaConfig {
    fn default() -> Self {
        Self {
            sweeps: 300,
            t_start: 4.0,
            t_end: 0.05,
            restarts: 2,
        }
    }
}

pub struct SaSolver {
    cfg: SaConfig,
    rng: Pcg32,
}

impl SaSolver {
    pub fn new(seed: u64, cfg: SaConfig) -> Self {
        Self {
            cfg,
            rng: Pcg32::new(seed, 0x5A5A),
        }
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, SaConfig::default())
    }

    /// Reset the RNG to a fresh stream keyed by `seed` (see
    /// `TabuSolver::reseed`; the device pool re-seeds per request).
    pub fn reseed(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed, 0x5A5A);
    }

    fn run_once(&mut self, ising: &Ising) -> SolveResult {
        let init: Vec<i8> = (0..ising.n)
            .map(|_| if self.rng.bernoulli(0.5) { 1 } else { -1 })
            .collect();
        self.run_from(ising, init)
    }

    /// One annealing run from an explicit start configuration (warm-start
    /// path: no init randomness is drawn; best-so-far starts at `init`,
    /// so the result is never worse than the hint).
    fn run_from(&mut self, ising: &Ising, init: Vec<i8>) -> SolveResult {
        let n = ising.n;
        debug_assert_eq!(init.len(), n);
        let mut s = init;
        let mut l = init_local_fields(ising, &s);
        let mut e = ising.energy(&s);
        let mut best_e = e;
        let mut best_s = s.clone();

        let sweeps = self.cfg.sweeps.max(1);
        let cool = (self.cfg.t_end / self.cfg.t_start).powf(1.0 / sweeps as f64);
        let mut t = self.cfg.t_start;
        for _ in 0..sweeps {
            for _ in 0..n {
                let i = self.rng.below(n as u32) as usize;
                let delta = -2.0 * s[i] as f64 * l[i];
                if delta <= 0.0 || self.rng.f64() < (-delta / t).exp() {
                    apply_flip(ising, &mut s, &mut l, i);
                    e += delta;
                    if e < best_e - 1e-12 {
                        best_e = e;
                        best_s.copy_from_slice(&s);
                    }
                }
            }
            t *= cool;
        }
        SolveResult {
            spins: best_s,
            energy: best_e,
        }
    }
}

impl IsingSolver for SaSolver {
    fn name(&self) -> &'static str {
        "sa"
    }

    fn solve(&mut self, ising: &Ising) -> SolveResult {
        let mut best: Option<SolveResult> = None;
        for _ in 0..self.cfg.restarts.max(1) {
            let r = self.run_once(ising);
            if best.as_ref().map_or(true, |b| r.energy < b.energy) {
                best = Some(r);
            }
        }
        best.unwrap()
    }

    fn solve_from(&mut self, ising: &Ising, init: &[i8]) -> SolveResult {
        debug_assert_eq!(init.len(), ising.n, "warm-start hint length mismatch");
        // first restart from the hint, remaining restarts cold; strict
        // `<` keeps the warm result on exact ties
        let mut best = self.run_from(ising, init.to_vec());
        for _ in 1..self.cfg.restarts.max(1) {
            let r = self.run_once(ising);
            if r.energy < best.energy {
                best = r;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::exact::ising_ground_exhaustive;

    fn random_ising(seed: u64, n: usize) -> Ising {
        let mut rng = Pcg32::seeded(seed);
        let mut ising = Ising::new(n);
        for i in 0..n {
            ising.h[i] = rng.range_f32(-1.5, 1.5);
            for j in (i + 1)..n {
                ising.set_pair(i, j, rng.range_f32(-1.0, 1.0));
            }
        }
        ising
    }

    #[test]
    fn finds_ground_state_on_small_instances() {
        for seed in 0..4 {
            let ising = random_ising(seed, 12);
            let (ge, _, _) = ising_ground_exhaustive(&ising);
            let r = SaSolver::seeded(seed + 10).solve(&ising);
            assert!(
                (r.energy - ge).abs() < 1e-6,
                "seed {seed}: sa {} vs ground {ge}",
                r.energy
            );
        }
    }

    #[test]
    fn reported_energy_matches_spins() {
        let ising = random_ising(7, 24);
        let r = SaSolver::seeded(2).solve(&ising);
        assert!((ising.energy(&r.spins) - r.energy).abs() < 1e-6);
    }

    #[test]
    fn deterministic_given_seed() {
        let ising = random_ising(8, 16);
        assert_eq!(
            SaSolver::seeded(4).solve(&ising).spins,
            SaSolver::seeded(4).solve(&ising).spins
        );
    }
}
