//! Greedy marginal-gain baseline — the classic approximate-inference
//! approach McDonald [3] motivates ("greedy search ... explored"); also
//! the repair heuristic's big brother and a useful fast warm start.
//!
//! Iteratively adds the sentence with the largest marginal Eq. 3 gain
//! until M are selected, O(n * M * M). Exact when λ = 0; otherwise a
//! heuristic that the Ising solvers must beat to justify the hardware.

use crate::ising::{EsProblem, Ising};

use super::{apply_flip, init_local_fields, IsingSolver, SelectionResult, SolveResult, TIE_EPS};

/// Greedy forward selection.
pub fn solve(p: &EsProblem) -> SelectionResult {
    let n = p.n();
    assert!(p.m <= n);
    let mut selected: Vec<usize> = Vec::with_capacity(p.m);
    let mut in_set = vec![false; n];
    // pair_pen[i] = 2 λ Σ_{j∈S} β_ij (ordered-pair count)
    let mut pair_pen = vec![0.0f64; n];
    let lambda = p.lambda as f64;

    for _ in 0..p.m {
        let mut best: Option<(usize, f64)> = None;
        for i in 0..n {
            if in_set[i] {
                continue;
            }
            let gain = p.mu[i] as f64 - pair_pen[i];
            if best.map_or(true, |(_, g)| gain > g) {
                best = Some((i, gain));
            }
        }
        let (i, _) = best.expect("m <= n guarantees a candidate");
        in_set[i] = true;
        selected.push(i);
        for j in 0..n {
            if !in_set[j] {
                pair_pen[j] += 2.0 * lambda * p.beta_ij(i, j) as f64;
            }
        }
    }
    selected.sort_unstable();
    SelectionResult {
        objective: p.objective(&selected),
        selected,
    }
}

/// Greedy with one pass of local exchange polish: try swapping each
/// selected sentence for each unselected one, keep improvements, repeat
/// until fixpoint (bounded). A stronger software baseline.
pub fn solve_with_exchange(p: &EsProblem, max_rounds: usize) -> SelectionResult {
    let mut cur = solve(p);
    let n = p.n();
    for _ in 0..max_rounds {
        let mut improved = false;
        'outer: for k in 0..cur.selected.len() {
            for cand in 0..n {
                if cur.selected.contains(&cand) {
                    continue;
                }
                let mut trial = cur.selected.clone();
                trial[k] = cand;
                trial.sort_unstable();
                let obj = p.objective(&trial);
                if obj > cur.objective + 1e-12 {
                    cur = SelectionResult {
                        selected: trial,
                        objective: obj,
                    };
                    improved = true;
                    break 'outer;
                }
            }
        }
        if !improved {
            break;
        }
    }
    cur
}

/// Deterministic steepest-descent Ising solver: repeatedly flip the spin
/// with the largest energy gain until no flip improves, breaking exact
/// ties toward the lowest index (the solver-wide rule — see
/// [`IsingSolver`] docs). Zero randomness, O(n) per flip via incremental
/// local fields.
///
/// In the solver portfolio this is the cheap hint-polisher: warm-started
/// from a cached near-match (`solve_from`) it converges in a handful of
/// flips, and its result is never worse than the hint. Cold solves start
/// from the field-aligned configuration (`s_i = -sign(h_i)`, ties to +1).
#[derive(Debug, Clone, Copy, Default)]
pub struct GreedyDescent;

impl GreedyDescent {
    pub fn new() -> Self {
        Self
    }

    /// Strict descent from `init` to the nearest local minimum.
    fn descend(ising: &Ising, mut s: Vec<i8>) -> SolveResult {
        let n = ising.n;
        let mut l = init_local_fields(ising, &s);
        let mut e = ising.energy(&s);
        loop {
            // best strictly-improving flip; strict `<` keeps the lowest
            // index on exact ties
            let mut chosen: Option<(usize, f64)> = None;
            for i in 0..n {
                let delta = -2.0 * s[i] as f64 * l[i];
                if delta < -TIE_EPS && chosen.map_or(true, |(_, d)| delta < d) {
                    chosen = Some((i, delta));
                }
            }
            match chosen {
                Some((i, delta)) => {
                    apply_flip(ising, &mut s, &mut l, i);
                    e += delta;
                }
                None => break, // local minimum: strict descent terminates
            }
        }
        SolveResult { spins: s, energy: e }
    }
}

impl IsingSolver for GreedyDescent {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn solve(&mut self, ising: &Ising) -> SolveResult {
        let init: Vec<i8> = ising
            .h
            .iter()
            .map(|&h| if h > 0.0 { -1 } else { 1 })
            .collect();
        Self::descend(ising, init)
    }

    fn solve_from(&mut self, ising: &Ising, init: &[i8]) -> SolveResult {
        debug_assert_eq!(init.len(), ising.n, "warm-start hint length mismatch");
        Self::descend(ising, init.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::exact;
    use crate::util::rng::Pcg32;

    fn random_es(seed: u64, n: usize, m: usize) -> EsProblem {
        let mut rng = Pcg32::seeded(seed);
        let mu: Vec<f32> = (0..n).map(|_| rng.range_f32(0.3, 0.95)).collect();
        let mut beta = vec![0.0f32; n * n];
        for i in 0..n {
            for j in (i + 1)..n {
                let b = rng.range_f32(0.2, 0.9);
                beta[i * n + j] = b;
                beta[j * n + i] = b;
            }
        }
        EsProblem { mu, beta, lambda: 0.6, m }
    }

    #[test]
    fn greedy_exact_when_no_redundancy() {
        let mut p = random_es(1, 12, 4);
        p.beta.iter_mut().for_each(|b| *b = 0.0);
        let g = solve(&p);
        let e = exact::solve_max(&p);
        assert!((g.objective - e.objective).abs() < 1e-9);
    }

    #[test]
    fn greedy_is_feasible_and_reasonable() {
        // dense positive redundancy hurts myopic selection badly (which is
        // why the paper reaches for global optimization); require mere
        // sanity from plain greedy and decent quality from greedy+exchange
        let mut gap_sum = 0.0;
        for seed in 0..5 {
            let p = random_es(seed, 15, 5);
            let g = solve(&p);
            assert_eq!(g.selected.len(), 5);
            let e = exact::solve_max(&p);
            assert!(g.objective <= e.objective + 1e-9);
            let x = solve_with_exchange(&p, 30);
            let gap = (e.objective - x.objective) / e.objective.abs().max(1e-9);
            gap_sum += gap;
            assert!(gap < 0.3, "seed {seed}: exchange gap {gap}");
        }
        assert!(gap_sum / 5.0 < 0.15, "mean exchange gap {}", gap_sum / 5.0);
    }

    #[test]
    fn exchange_never_hurts() {
        for seed in 0..5 {
            let p = random_es(seed + 50, 14, 4);
            let g = solve(&p);
            let x = solve_with_exchange(&p, 20);
            assert!(x.objective >= g.objective - 1e-12);
            assert_eq!(x.selected.len(), 4);
        }
    }

    #[test]
    fn incremental_gain_bookkeeping_is_exact() {
        let p = random_es(9, 10, 3);
        let g = solve(&p);
        assert!((p.objective(&g.selected) - g.objective).abs() < 1e-12);
    }

    #[test]
    fn descent_reaches_a_local_minimum_deterministically() {
        let mut rng = Pcg32::seeded(31);
        let mut ising = Ising::new(14);
        for i in 0..14 {
            ising.h[i] = rng.range_f32(-1.5, 1.5);
            for j in (i + 1)..14 {
                ising.set_pair(i, j, rng.range_f32(-1.0, 1.0));
            }
        }
        let a = GreedyDescent::new().solve(&ising);
        let b = GreedyDescent::new().solve(&ising);
        assert_eq!(a.spins, b.spins, "descent must be deterministic");
        assert!((ising.energy(&a.spins) - a.energy).abs() < 1e-9);
        // local minimality: no single flip improves
        for i in 0..14 {
            let mut s = a.spins.clone();
            s[i] = -s[i];
            assert!(ising.energy(&s) >= a.energy - 1e-9, "flip {i} improves");
        }
    }

    #[test]
    fn descent_from_a_hint_never_returns_worse_than_the_hint() {
        let mut rng = Pcg32::seeded(32);
        let mut ising = Ising::new(12);
        for i in 0..12 {
            ising.h[i] = rng.range_f32(-1.0, 1.0);
            for j in (i + 1)..12 {
                ising.set_pair(i, j, rng.range_f32(-0.8, 0.8));
            }
        }
        for trial in 0..10 {
            let hint: Vec<i8> = (0..12)
                .map(|_| if rng.bernoulli(0.5) { 1 } else { -1 })
                .collect();
            let r = GreedyDescent::new().solve_from(&ising, &hint);
            assert!(
                r.energy <= ising.energy(&hint) + 1e-9,
                "trial {trial}: descent went uphill"
            );
        }
    }
}
