//! Greedy marginal-gain baseline — the classic approximate-inference
//! approach McDonald [3] motivates ("greedy search ... explored"); also
//! the repair heuristic's big brother and a useful fast warm start.
//!
//! Iteratively adds the sentence with the largest marginal Eq. 3 gain
//! until M are selected, O(n * M * M). Exact when λ = 0; otherwise a
//! heuristic that the Ising solvers must beat to justify the hardware.

use crate::ising::{EsProblem, Ising, QuantIsing};

use super::kernel::{KernelScratch, QuantSolve, SolveScratch, SolverKernel};
use super::{IsingSolver, SelectionResult, SolveResult};

/// Greedy forward selection.
pub fn solve(p: &EsProblem) -> SelectionResult {
    let n = p.n();
    assert!(p.m <= n);
    let mut selected: Vec<usize> = Vec::with_capacity(p.m);
    let mut in_set = vec![false; n];
    // pair_pen[i] = 2 λ Σ_{j∈S} β_ij (ordered-pair count)
    let mut pair_pen = vec![0.0f64; n];
    let lambda = p.lambda as f64;

    for _ in 0..p.m {
        let mut best: Option<(usize, f64)> = None;
        for i in 0..n {
            if in_set[i] {
                continue;
            }
            let gain = p.mu[i] as f64 - pair_pen[i];
            if best.map_or(true, |(_, g)| gain > g) {
                best = Some((i, gain));
            }
        }
        let (i, _) = best.expect("m <= n guarantees a candidate");
        in_set[i] = true;
        selected.push(i);
        for j in 0..n {
            if !in_set[j] {
                pair_pen[j] += 2.0 * lambda * p.beta_ij(i, j) as f64;
            }
        }
    }
    selected.sort_unstable();
    SelectionResult {
        objective: p.objective(&selected),
        selected,
    }
}

/// Greedy with one pass of local exchange polish: try swapping each
/// selected sentence for each unselected one, keep improvements, repeat
/// until fixpoint (bounded). A stronger software baseline.
pub fn solve_with_exchange(p: &EsProblem, max_rounds: usize) -> SelectionResult {
    let mut cur = solve(p);
    let n = p.n();
    for _ in 0..max_rounds {
        let mut improved = false;
        'outer: for k in 0..cur.selected.len() {
            for cand in 0..n {
                if cur.selected.contains(&cand) {
                    continue;
                }
                let mut trial = cur.selected.clone();
                trial[k] = cand;
                trial.sort_unstable();
                let obj = p.objective(&trial);
                if obj > cur.objective + 1e-12 {
                    cur = SelectionResult {
                        selected: trial,
                        objective: obj,
                    };
                    improved = true;
                    break 'outer;
                }
            }
        }
        if !improved {
            break;
        }
    }
    cur
}

/// Deterministic steepest-descent Ising solver: repeatedly flip the spin
/// with the largest energy gain until no flip improves, breaking exact
/// ties toward the lowest index (the solver-wide rule — see
/// [`IsingSolver`] docs). Zero randomness, O(n) per flip via incremental
/// local fields. The descent is generic over [`SolverKernel`]:
/// integer-valued instances run on exact `i64` arithmetic, bit-identical
/// to the `f64` path (pinned below).
///
/// In the solver portfolio this is the cheap hint-polisher: warm-started
/// from a cached near-match (`solve_from`) it converges in a handful of
/// flips, and its result is never worse than the hint. Cold solves start
/// from the field-aligned configuration (`s_i = -sign(h_i)`, ties to +1).
#[derive(Debug, Clone, Default)]
pub struct GreedyDescent {
    scratch: SolveScratch,
}

impl GreedyDescent {
    /// Fresh descent solver (owns its scratch workspace).
    pub fn new() -> Self {
        Self::default()
    }

    /// Solve, picking the coefficient domain (see `TabuSolver::solve_any`).
    fn solve_any(&mut self, ising: &Ising, init: Option<&[i8]>) -> SolveResult {
        let scratch = &mut self.scratch;
        if scratch.quant.try_copy_from(ising) {
            let energy = descend_core(&scratch.quant, &mut scratch.int, init);
            SolveResult {
                spins: scratch.int.best.clone(),
                energy,
            }
        } else {
            let energy = descend_core(ising, &mut scratch.fp, init);
            SolveResult {
                spins: scratch.fp.best.clone(),
                energy,
            }
        }
    }

    /// Force the `f64` kernel — the reference entry the integer path is
    /// pinned against (see `TabuSolver::solve_reference_f64`).
    pub fn solve_reference_f64(&mut self, ising: &Ising) -> SolveResult {
        let energy = descend_core(ising, &mut self.scratch.fp, None);
        SolveResult {
            spins: self.scratch.fp.best.clone(),
            energy,
        }
    }
}

/// Strict steepest descent to the nearest local minimum, from `init` when
/// given or the field-aligned cold start otherwise. Final spins land in
/// `ks.best`; returns their energy.
pub(crate) fn descend_core<K: SolverKernel>(
    k: &K,
    ks: &mut KernelScratch<K::Acc>,
    init: Option<&[i8]>,
) -> f64 {
    let n = k.n();
    debug_assert!(init.map_or(true, |h| h.len() == n), "warm-start hint length mismatch");
    ks.prepare(n);
    match init {
        Some(h) => ks.spins.copy_from_slice(h),
        None => k.cold_init(&mut ks.spins),
    }
    k.local_fields_into(&ks.spins, &mut ks.l);
    let mut e = k.energy_acc(&ks.spins);
    loop {
        // best strictly-improving flip; strict `<` keeps the lowest
        // index on exact ties
        let mut chosen: Option<(usize, K::Acc)> = None;
        for i in 0..n {
            let delta = K::flip_delta(&ks.spins, &ks.l, i);
            if K::improves(delta) && chosen.map_or(true, |(_, d)| delta < d) {
                chosen = Some((i, delta));
            }
        }
        match chosen {
            Some((i, delta)) => {
                k.apply_flip_acc(&mut ks.spins, &mut ks.l, i);
                e += delta;
            }
            None => break, // local minimum: strict descent terminates
        }
    }
    ks.best.copy_from_slice(&ks.spins);
    K::to_f64(e)
}

impl IsingSolver for GreedyDescent {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn solve(&mut self, ising: &Ising) -> SolveResult {
        self.solve_any(ising, None)
    }

    fn solve_from(&mut self, ising: &Ising, init: &[i8]) -> SolveResult {
        debug_assert_eq!(init.len(), ising.n, "warm-start hint length mismatch");
        self.solve_any(ising, Some(init))
    }

    fn quant_kernel(&mut self) -> Option<&mut dyn QuantSolve> {
        Some(self)
    }
}

impl QuantSolve for GreedyDescent {
    fn solve_quant_into(&mut self, q: &QuantIsing, out: &mut Vec<i8>) -> f64 {
        let energy = descend_core(q, &mut self.scratch.int, None);
        out.clear();
        out.extend_from_slice(&self.scratch.int.best);
        energy
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::exact;
    use crate::util::rng::Pcg32;

    fn random_es(seed: u64, n: usize, m: usize) -> EsProblem {
        let mut rng = Pcg32::seeded(seed);
        let mu: Vec<f32> = (0..n).map(|_| rng.range_f32(0.3, 0.95)).collect();
        let mut beta = vec![0.0f32; n * n];
        for i in 0..n {
            for j in (i + 1)..n {
                let b = rng.range_f32(0.2, 0.9);
                beta[i * n + j] = b;
                beta[j * n + i] = b;
            }
        }
        EsProblem { mu, beta, lambda: 0.6, m }
    }

    #[test]
    fn greedy_exact_when_no_redundancy() {
        let mut p = random_es(1, 12, 4);
        p.beta.iter_mut().for_each(|b| *b = 0.0);
        let g = solve(&p);
        let e = exact::solve_max(&p);
        assert!((g.objective - e.objective).abs() < 1e-9);
    }

    #[test]
    fn greedy_is_feasible_and_reasonable() {
        // dense positive redundancy hurts myopic selection badly (which is
        // why the paper reaches for global optimization); require mere
        // sanity from plain greedy and decent quality from greedy+exchange
        let mut gap_sum = 0.0;
        for seed in 0..5 {
            let p = random_es(seed, 15, 5);
            let g = solve(&p);
            assert_eq!(g.selected.len(), 5);
            let e = exact::solve_max(&p);
            assert!(g.objective <= e.objective + 1e-9);
            let x = solve_with_exchange(&p, 30);
            let gap = (e.objective - x.objective) / e.objective.abs().max(1e-9);
            gap_sum += gap;
            assert!(gap < 0.3, "seed {seed}: exchange gap {gap}");
        }
        assert!(gap_sum / 5.0 < 0.15, "mean exchange gap {}", gap_sum / 5.0);
    }

    #[test]
    fn exchange_never_hurts() {
        for seed in 0..5 {
            let p = random_es(seed + 50, 14, 4);
            let g = solve(&p);
            let x = solve_with_exchange(&p, 20);
            assert!(x.objective >= g.objective - 1e-12);
            assert_eq!(x.selected.len(), 4);
        }
    }

    #[test]
    fn incremental_gain_bookkeeping_is_exact() {
        let p = random_es(9, 10, 3);
        let g = solve(&p);
        assert!((p.objective(&g.selected) - g.objective).abs() < 1e-12);
    }

    #[test]
    fn descent_reaches_a_local_minimum_deterministically() {
        let mut rng = Pcg32::seeded(31);
        let mut ising = Ising::new(14);
        for i in 0..14 {
            ising.h[i] = rng.range_f32(-1.5, 1.5);
            for j in (i + 1)..14 {
                ising.set_pair(i, j, rng.range_f32(-1.0, 1.0));
            }
        }
        let a = GreedyDescent::new().solve(&ising);
        let b = GreedyDescent::new().solve(&ising);
        assert_eq!(a.spins, b.spins, "descent must be deterministic");
        assert!((ising.energy(&a.spins) - a.energy).abs() < 1e-9);
        // local minimality: no single flip improves
        for i in 0..14 {
            let mut s = a.spins.clone();
            s[i] = -s[i];
            assert!(ising.energy(&s) >= a.energy - 1e-9, "flip {i} improves");
        }
    }

    #[test]
    fn integer_kernel_is_bit_identical_to_f64_on_quantized_instances() {
        // acceptance pin (greedy): cold descent AND warm descent return
        // the same spins and bitwise-equal energy in both domains
        use crate::cobi::testutil::quantized_glass;
        let mut rng = Pcg32::seeded(33);
        for seed in 0..6 {
            for n in [5, 12, 20, 33] {
                let inst = quantized_glass(3000 + seed, n);
                let a = GreedyDescent::new().solve_reference_f64(&inst);
                let b = GreedyDescent::new().solve(&inst);
                assert_eq!(a.spins, b.spins, "seed {seed} n {n}");
                assert_eq!(a.energy.to_bits(), b.energy.to_bits(), "seed {seed} n {n}");

                let hint: Vec<i8> = (0..n)
                    .map(|_| if rng.bernoulli(0.5) { 1 } else { -1 })
                    .collect();
                let wa = {
                    let mut g = GreedyDescent::new();
                    let e = descend_core(&inst, &mut g.scratch.fp, Some(&hint));
                    (g.scratch.fp.best.clone(), e)
                };
                let wb = GreedyDescent::new().solve_from(&inst, &hint);
                assert_eq!(wa.0, wb.spins, "warm seed {seed} n {n}");
                assert_eq!(wa.1.to_bits(), wb.energy.to_bits(), "warm seed {seed} n {n}");
            }
        }
    }

    #[test]
    fn descent_from_a_hint_never_returns_worse_than_the_hint() {
        let mut rng = Pcg32::seeded(32);
        let mut ising = Ising::new(12);
        for i in 0..12 {
            ising.h[i] = rng.range_f32(-1.0, 1.0);
            for j in (i + 1)..12 {
                ising.set_pair(i, j, rng.range_f32(-0.8, 0.8));
            }
        }
        for trial in 0..10 {
            let hint: Vec<i8> = (0..12)
                .map(|_| if rng.bernoulli(0.5) { 1 } else { -1 })
                .collect();
            let r = GreedyDescent::new().solve_from(&ising, &hint);
            assert!(
                r.energy <= ising.energy(&hint) + 1e-9,
                "trial {trial}: descent went uphill"
            );
        }
    }
}
