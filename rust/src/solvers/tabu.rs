//! Tabu search over Ising instances (paper's software baseline [7], [25]).
//!
//! Tenure-based single-flip Tabu with aspiration and restarts, using the
//! incremental local-field machinery from `solvers::kernel` (O(n) per
//! move). This is the solver the paper runs "under the same precision as
//! COBI" in Figs 1–3/5–8; its budget defaults reproduce a dwave-tabu-like
//! effort profile on 10–64 spin instances.
//!
//! The inner loop is generic over [`SolverKernel`]: integer-valued
//! instances (every quantized Hamiltonian) run on `i64` accumulators over
//! `i32`/`i16` coefficients; everything else takes the original `f64`
//! path. The two are bit-identical on quantized instances (see
//! `ising::quant_model`), pinned by the equivalence test below, so the
//! domain switch is invisible to callers.

use crate::ising::{Ising, QuantIsing};
use crate::util::rng::Pcg32;

use super::kernel::{KernelScratch, QuantSolve, SolveScratch, SolverKernel};
use super::{IsingSolver, SolveResult};

/// Tabu-search parameters.
#[derive(Debug, Clone)]
pub struct TabuConfig {
    /// Tabu tenure as a fraction of n (clamped to >= 4 moves).
    pub tenure_frac: f64,
    /// Moves per restart, as a multiple of n.
    pub moves_per_spin: usize,
    /// Independent restarts from random configurations.
    pub restarts: usize,
}

impl Default for TabuConfig {
    fn default() -> Self {
        Self {
            tenure_frac: 0.25,
            moves_per_spin: 40,
            restarts: 3,
        }
    }
}

/// Tabu search — the paper's software baseline solver.
pub struct TabuSolver {
    cfg: TabuConfig,
    rng: Pcg32,
    scratch: SolveScratch,
}

impl TabuSolver {
    /// Solver with explicit parameters.
    pub fn new(seed: u64, cfg: TabuConfig) -> Self {
        Self {
            cfg,
            rng: Pcg32::new(seed, 0x7AB0),
            scratch: SolveScratch::default(),
        }
    }

    /// Solver with default parameters, seeded.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, TabuConfig::default())
    }

    /// Reset the RNG to a fresh stream keyed by `seed` — the device pool
    /// re-seeds before every request so results depend only on the
    /// request seed, never on dispatch order. The scratch workspace is
    /// untouched: it carries no solve state across requests, only
    /// capacity.
    pub fn reseed(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed, 0x7AB0);
    }

    /// Solve, picking the coefficient domain: integer-valued instances
    /// run the `i64` kernel, others the `f64` kernel — bit-identical
    /// results wherever both apply.
    fn solve_any(&mut self, ising: &Ising, warm: Option<&[i8]>) -> SolveResult {
        let Self { cfg, rng, scratch } = self;
        if scratch.quant.try_copy_from(ising) {
            let energy = tabu_core(&scratch.quant, cfg, rng, &mut scratch.int, warm);
            SolveResult {
                spins: scratch.int.best.clone(),
                energy,
            }
        } else {
            let energy = tabu_core(ising, cfg, rng, &mut scratch.fp, warm);
            SolveResult {
                spins: scratch.fp.best.clone(),
                energy,
            }
        }
    }

    /// Force the `f64` kernel regardless of the instance's domain — the
    /// reference entry the integer path is pinned against (equivalence
    /// tests, domain microbenches). Consumes the RNG exactly like
    /// [`IsingSolver::solve`].
    pub fn solve_reference_f64(&mut self, ising: &Ising) -> SolveResult {
        let Self { cfg, rng, scratch } = self;
        let energy = tabu_core(ising, cfg, rng, &mut scratch.fp, None);
        SolveResult {
            spins: scratch.fp.best.clone(),
            energy,
        }
    }
}

/// Restart wrapper over [`tabu_run`]: restart 0 starts from `warm` when
/// given (drawing no init randomness), later restarts from random
/// configurations; best-across-restarts kept on strict `<` (earlier
/// restart wins exact ties). Returns the best energy; best spins land in
/// `ks.best`.
pub(crate) fn tabu_core<K: SolverKernel>(
    k: &K,
    cfg: &TabuConfig,
    rng: &mut Pcg32,
    ks: &mut KernelScratch<K::Acc>,
    warm: Option<&[i8]>,
) -> f64 {
    let n = k.n();
    debug_assert!(warm.map_or(true, |h| h.len() == n), "warm-start hint length mismatch");
    ks.prepare(n);
    let mut overall: Option<K::Acc> = None;
    for r in 0..cfg.restarts.max(1) {
        match warm {
            Some(h) if r == 0 => ks.spins.copy_from_slice(h),
            _ => {
                for x in ks.spins.iter_mut() {
                    *x = if rng.bernoulli(0.5) { 1 } else { -1 };
                }
            }
        }
        let e = tabu_run(k, cfg, rng, ks);
        if overall.map_or(true, |b| e < b) {
            overall = Some(e);
            ks.best.copy_from_slice(&ks.run_best);
        }
    }
    K::to_f64(overall.expect("restarts >= 1"))
}

/// One tabu run from the configuration in `ks.spins` (the RNG is touched
/// only by all-tabu kicks). Best spins of the run land in `ks.run_best`.
fn tabu_run<K: SolverKernel>(
    k: &K,
    cfg: &TabuConfig,
    rng: &mut Pcg32,
    ks: &mut KernelScratch<K::Acc>,
) -> K::Acc {
    let n = k.n();
    let tenure = ((n as f64 * cfg.tenure_frac) as usize).max(4);
    let max_moves = cfg.moves_per_spin * n;

    k.local_fields_into(&ks.spins, &mut ks.l);
    let mut e = k.energy_acc(&ks.spins);
    let mut best_e = e;
    ks.run_best.copy_from_slice(&ks.spins);
    // tabu_until[i]: first move index at which flipping i is allowed
    ks.tabu_until.clear();
    ks.tabu_until.resize(n, 0);

    for mv in 0..max_moves {
        // pick the best admissible flip; strict `<` means exact ties
        // keep the earlier (lowest-index) candidate — the solver-wide
        // tie-break rule (see `IsingSolver` docs)
        let mut chosen: Option<(usize, K::Acc)> = None;
        for i in 0..n {
            let delta = K::flip_delta(&ks.spins, &ks.l, i);
            let admissible = ks.tabu_until[i] <= mv || K::lt_margin(e + delta, best_e);
            if !admissible {
                continue;
            }
            if chosen.map_or(true, |(_, d)| delta < d) {
                chosen = Some((i, delta));
            }
        }
        // all moves tabu (tiny n): take a random kick
        let (i, delta) = match chosen {
            Some(c) => c,
            None => {
                let i = rng.below(n as u32) as usize;
                (i, K::flip_delta(&ks.spins, &ks.l, i))
            }
        };
        k.apply_flip_acc(&mut ks.spins, &mut ks.l, i);
        e += delta;
        ks.tabu_until[i] = mv + 1 + tenure;
        if K::lt_margin(e, best_e) {
            best_e = e;
            ks.run_best.copy_from_slice(&ks.spins);
        }
    }
    best_e
}

impl IsingSolver for TabuSolver {
    fn name(&self) -> &'static str {
        "tabu"
    }

    fn solve(&mut self, ising: &Ising) -> SolveResult {
        self.solve_any(ising, None)
    }

    fn solve_from(&mut self, ising: &Ising, init: &[i8]) -> SolveResult {
        debug_assert_eq!(init.len(), ising.n, "warm-start hint length mismatch");
        // first restart from the hint, remaining restarts cold; strict
        // `<` keeps the warm result on exact ties
        self.solve_any(ising, Some(init))
    }

    fn quant_kernel(&mut self) -> Option<&mut dyn QuantSolve> {
        Some(self)
    }
}

impl QuantSolve for TabuSolver {
    fn solve_quant_into(&mut self, q: &QuantIsing, out: &mut Vec<i8>) -> f64 {
        let Self { cfg, rng, scratch } = self;
        let energy = tabu_core(q, cfg, rng, &mut scratch.int, None);
        out.clear();
        out.extend_from_slice(&scratch.int.best);
        energy
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cobi::testutil::quantized_glass;
    use crate::solvers::exact::ising_ground_exhaustive;

    fn random_ising(seed: u64, n: usize) -> Ising {
        let mut rng = Pcg32::seeded(seed);
        let mut ising = Ising::new(n);
        for i in 0..n {
            ising.h[i] = rng.range_f32(-1.5, 1.5);
            for j in (i + 1)..n {
                ising.set_pair(i, j, rng.range_f32(-1.0, 1.0));
            }
        }
        ising
    }

    #[test]
    fn finds_ground_state_on_small_instances() {
        // dwave-tabu-grade reliability on 12-spin glasses
        for seed in 0..5 {
            let ising = random_ising(seed, 12);
            let (ge, _, _) = ising_ground_exhaustive(&ising);
            let mut solver = TabuSolver::seeded(seed + 100);
            let r = solver.solve(&ising);
            assert!(
                (r.energy - ge).abs() < 1e-6,
                "seed {seed}: tabu {} vs ground {ge}",
                r.energy
            );
        }
    }

    #[test]
    fn energy_field_consistent_with_spins() {
        let ising = random_ising(9, 20);
        let mut solver = TabuSolver::seeded(1);
        let r = solver.solve(&ising);
        assert!((ising.energy(&r.spins) - r.energy).abs() < 1e-6);
    }

    #[test]
    fn deterministic_given_seed() {
        let ising = random_ising(10, 16);
        let a = TabuSolver::seeded(5).solve(&ising);
        let b = TabuSolver::seeded(5).solve(&ising);
        assert_eq!(a.spins, b.spins);
    }

    #[test]
    fn respects_move_budget_scaling() {
        // a 1-move-per-spin budget must not loop forever and still returns
        // a valid configuration
        let ising = random_ising(11, 32);
        let mut solver = TabuSolver::new(
            3,
            TabuConfig {
                tenure_frac: 0.25,
                moves_per_spin: 1,
                restarts: 1,
            },
        );
        let r = solver.solve(&ising);
        assert_eq!(r.spins.len(), 32);
        assert!(r.spins.iter().all(|&v| v == 1 || v == -1));
    }

    #[test]
    fn integer_kernel_is_bit_identical_to_f64_on_quantized_instances() {
        // the acceptance pin: on every quantized instance the integer
        // path (what `solve` auto-selects) must return the SAME spins and
        // bitwise-equal energy as the f64 reference kernel
        for seed in 0..6 {
            for n in [5, 12, 20, 33] {
                let inst = quantized_glass(1000 + seed, n);
                let a = TabuSolver::seeded(seed).solve_reference_f64(&inst);
                let b = TabuSolver::seeded(seed).solve(&inst);
                assert_eq!(a.spins, b.spins, "seed {seed} n {n}");
                assert_eq!(
                    a.energy.to_bits(),
                    b.energy.to_bits(),
                    "seed {seed} n {n}: {} vs {}",
                    a.energy,
                    b.energy
                );
            }
        }
    }

    #[test]
    fn integer_kernel_warm_start_matches_f64_path() {
        let inst = quantized_glass(77, 14);
        let hint: Vec<i8> = (0..14).map(|i| if i % 3 == 0 { 1 } else { -1 }).collect();
        // the fractional twin forces the f64 path through the public API:
        // scale by a non-representable factor then back? Instead pin the
        // warm path against a same-seeded reference via the core directly.
        let mut a = TabuSolver::seeded(4);
        let ra = {
            let TabuSolver { cfg, rng, scratch } = &mut a;
            let e = tabu_core(&inst, cfg, rng, &mut scratch.fp, Some(&hint));
            (scratch.fp.best.clone(), e)
        };
        let rb = TabuSolver::seeded(4).solve_from(&inst, &hint);
        assert_eq!(ra.0, rb.spins);
        assert_eq!(ra.1.to_bits(), rb.energy.to_bits());
    }

    #[test]
    fn solve_quant_into_reuses_the_output_buffer() {
        let inst = quantized_glass(88, 12);
        let mut q = QuantIsing::default();
        assert!(q.try_copy_from(&inst));
        let mut out = Vec::new();
        let mut solver = TabuSolver::seeded(6);
        let e1 = solver.solve_quant_into(&q, &mut out);
        assert_eq!(out.len(), 12);
        assert_eq!(q.energy(&out) as f64, e1);
        // same solver, fresh RNG stream: identical to the Ising-facade
        // solve on the f32 twin
        let mut facade = TabuSolver::seeded(6);
        let r = facade.solve(&inst);
        assert_eq!(r.spins, out);
        assert_eq!(r.energy.to_bits(), e1.to_bits());
    }
}
