//! Tabu search over Ising instances (paper's software baseline [7], [25]).
//!
//! Tenure-based single-flip Tabu with aspiration and restarts, using the
//! incremental local-field machinery from `solvers::` (O(n) per move).
//! This is the solver the paper runs "under the same precision as COBI"
//! in Figs 1–3/5–8; its budget defaults reproduce a dwave-tabu-like
//! effort profile on 10–64 spin instances.

use crate::ising::Ising;
use crate::util::rng::Pcg32;

use super::{apply_flip, init_local_fields, IsingSolver, SolveResult};

#[derive(Debug, Clone)]
pub struct TabuConfig {
    /// Tabu tenure as a fraction of n (clamped to >= 4 moves).
    pub tenure_frac: f64,
    /// Moves per restart, as a multiple of n.
    pub moves_per_spin: usize,
    /// Independent restarts from random configurations.
    pub restarts: usize,
}

impl Default for TabuConfig {
    fn default() -> Self {
        Self {
            tenure_frac: 0.25,
            moves_per_spin: 40,
            restarts: 3,
        }
    }
}

pub struct TabuSolver {
    cfg: TabuConfig,
    rng: Pcg32,
}

impl TabuSolver {
    pub fn new(seed: u64, cfg: TabuConfig) -> Self {
        Self {
            cfg,
            rng: Pcg32::new(seed, 0x7AB0),
        }
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, TabuConfig::default())
    }

    /// Reset the RNG to a fresh stream keyed by `seed` — the device pool
    /// re-seeds before every request so results depend only on the
    /// request seed, never on dispatch order.
    pub fn reseed(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed, 0x7AB0);
    }

    fn run_once(&mut self, ising: &Ising) -> SolveResult {
        let init: Vec<i8> = (0..ising.n)
            .map(|_| if self.rng.bernoulli(0.5) { 1 } else { -1 })
            .collect();
        self.run_from(ising, init)
    }

    /// One tabu run starting from an explicit configuration (the
    /// warm-start path draws no init randomness; the RNG is touched only
    /// by all-tabu kicks, exactly as in a cold run).
    fn run_from(&mut self, ising: &Ising, init: Vec<i8>) -> SolveResult {
        let n = ising.n;
        debug_assert_eq!(init.len(), n);
        let tenure = ((n as f64 * self.cfg.tenure_frac) as usize).max(4);
        let max_moves = self.cfg.moves_per_spin * n;

        let mut s = init;
        let mut l = init_local_fields(ising, &s);
        let mut e = ising.energy(&s);
        let mut best_e = e;
        let mut best_s = s.clone();
        // tabu_until[i]: first move index at which flipping i is allowed
        let mut tabu_until = vec![0usize; n];

        for mv in 0..max_moves {
            // pick the best admissible flip; strict `<` means exact ties
            // keep the earlier (lowest-index) candidate — the solver-wide
            // tie-break rule (see `IsingSolver` docs)
            let mut chosen: Option<(usize, f64)> = None;
            for i in 0..n {
                let delta = -2.0 * s[i] as f64 * l[i];
                let admissible = tabu_until[i] <= mv || e + delta < best_e - 1e-12;
                if !admissible {
                    continue;
                }
                if chosen.map_or(true, |(_, d)| delta < d) {
                    chosen = Some((i, delta));
                }
            }
            // all moves tabu (tiny n): take a random kick
            let (i, delta) =
                chosen.unwrap_or_else(|| (self.rng.below(n as u32) as usize, f64::NAN));
            let delta = if delta.is_nan() {
                -2.0 * s[i] as f64 * l[i]
            } else {
                delta
            };
            apply_flip(ising, &mut s, &mut l, i);
            e += delta;
            tabu_until[i] = mv + 1 + tenure;
            if e < best_e - 1e-12 {
                best_e = e;
                best_s.copy_from_slice(&s);
            }
        }
        SolveResult {
            spins: best_s,
            energy: best_e,
        }
    }
}

impl IsingSolver for TabuSolver {
    fn name(&self) -> &'static str {
        "tabu"
    }

    fn solve(&mut self, ising: &Ising) -> SolveResult {
        let mut best: Option<SolveResult> = None;
        for _ in 0..self.cfg.restarts.max(1) {
            let r = self.run_once(ising);
            if best.as_ref().map_or(true, |b| r.energy < b.energy) {
                best = Some(r);
            }
        }
        best.unwrap()
    }

    fn solve_from(&mut self, ising: &Ising, init: &[i8]) -> SolveResult {
        debug_assert_eq!(init.len(), ising.n, "warm-start hint length mismatch");
        // first restart from the hint, remaining restarts cold; strict
        // `<` keeps the warm result on exact ties
        let mut best = self.run_from(ising, init.to_vec());
        for _ in 1..self.cfg.restarts.max(1) {
            let r = self.run_once(ising);
            if r.energy < best.energy {
                best = r;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::exact::ising_ground_exhaustive;

    fn random_ising(seed: u64, n: usize) -> Ising {
        let mut rng = Pcg32::seeded(seed);
        let mut ising = Ising::new(n);
        for i in 0..n {
            ising.h[i] = rng.range_f32(-1.5, 1.5);
            for j in (i + 1)..n {
                ising.set_pair(i, j, rng.range_f32(-1.0, 1.0));
            }
        }
        ising
    }

    #[test]
    fn finds_ground_state_on_small_instances() {
        // dwave-tabu-grade reliability on 12-spin glasses
        for seed in 0..5 {
            let ising = random_ising(seed, 12);
            let (ge, _, _) = ising_ground_exhaustive(&ising);
            let mut solver = TabuSolver::seeded(seed + 100);
            let r = solver.solve(&ising);
            assert!(
                (r.energy - ge).abs() < 1e-6,
                "seed {seed}: tabu {} vs ground {ge}",
                r.energy
            );
        }
    }

    #[test]
    fn energy_field_consistent_with_spins() {
        let ising = random_ising(9, 20);
        let mut solver = TabuSolver::seeded(1);
        let r = solver.solve(&ising);
        assert!((ising.energy(&r.spins) - r.energy).abs() < 1e-6);
    }

    #[test]
    fn deterministic_given_seed() {
        let ising = random_ising(10, 16);
        let a = TabuSolver::seeded(5).solve(&ising);
        let b = TabuSolver::seeded(5).solve(&ising);
        assert_eq!(a.spins, b.spins);
    }

    #[test]
    fn respects_move_budget_scaling() {
        // a 1-move-per-spin budget must not loop forever and still returns
        // a valid configuration
        let ising = random_ising(11, 32);
        let mut solver = TabuSolver::new(
            3,
            TabuConfig {
                tenure_frac: 0.25,
                moves_per_spin: 1,
                restarts: 1,
            },
        );
        let r = solver.solve(&ising);
        assert_eq!(r.spins.len(), 32);
        assert!(r.spins.iter().all(|&v| v == 1 || v == -1));
    }
}
