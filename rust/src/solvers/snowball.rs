//! Snowball-style sharded parallel-spin MCMC solver (PAPERS.md: dual-mode
//! spin selection with asynchronous updates across parallel units).
//!
//! Every other software backend in this crate is a serial single-spin
//! sweep, so the largest merged subproblems (tree-strategy root merges,
//! stream frontier compressions) leave host cores idle exactly where
//! latency matters most. Snowball shards the spin vector across logical
//! parallel units and lets every shard propose flips concurrently against
//! a stale snapshot of its neighbours — the Snowball chip's asynchronous
//! update model, reproduced in software.
//!
//! ## Logical asynchrony (DESIGN.md decision #19)
//!
//! Asynchrony here is **logical, not wall-clock**. Each solve draws one
//! schedule seed from the solver's request RNG stream; every shard then
//! runs its own PCG stream ([`SNOWBALL_SCHEDULE_STREAM`]) derived from
//! that seed, fixing exactly which spins the shard proposes at each
//! logical tick. An epoch is the barrier unit: shards work from the same
//! epoch-start snapshot (spins + local fields), apply their own accepted
//! flips to a private view, and the barrier merges shard results in shard
//! order. Nothing a shard computes depends on when — or on which OS
//! thread — another shard ran, so a `T`-thread execution is bit-identical
//! to the 1-thread sequential replay. `COBI_SNOWBALL_THREADS` (or
//! [`SnowballConfig::threads`]) chooses physical parallelism freely
//! without touching one output byte.
//!
//! ## Dual-mode selection
//!
//! * **Uniform sweep mode** (`n <= focus_threshold`): each shard proposes
//!   its owned spins in ascending index order once per epoch, each spin
//!   participating with probability [`SnowballConfig::participation`] —
//!   the Bernoulli draw is the symmetry breaker that keeps antiparallel
//!   shard pairs from oscillating forever on stale data.
//! * **Focus mode** (`n > focus_threshold`): each shard draws
//!   tournament-of-2 candidates from its schedule stream and proposes the
//!   one with the better (lower) stale flip delta, ties to the lower spin
//!   index — Metropolis-weighted attention toward improving moves without
//!   a full softmax over n spins.
//!
//! Accepts follow the SA rule: downhill-or-flat moves are free (no RNG
//! draw — identical draw order across coefficient domains), uphill moves
//! go through Metropolis on the exact delta. The epoch loop is generic
//! over [`SolverKernel`], so integer-valued instances run on `i64`
//! accumulators bit-identical to the `f64` reference path, pinned by the
//! equivalence test below. A final strict greedy descent (no randomness)
//! polishes the best barrier state to a local minimum.

use crate::ising::{Ising, QuantIsing};
use crate::util::rng::{Pcg32, SplitMix64};

use super::kernel::{KernelScratch, QuantSolve, SolveScratch, SolverKernel};
use super::{IsingSolver, SolveResult};

/// RNG stream of the solver's request-level randomness (restart inits and
/// the per-run schedule seed). Distinct from every other named stream —
/// see the audit test in `util::rng`.
pub const SNOWBALL_STREAM: u64 = 0x5B07_BA11;

/// RNG stream of the per-shard logical update schedules. Each shard's
/// generator is `Pcg32::new(mix(schedule_seed, shard), STREAM)`, so shard
/// schedules are independent of thread count and dispatch interleaving.
pub const SNOWBALL_SCHEDULE_STREAM: u64 = 0x5B07_5CED;

/// Environment variable selecting how many OS threads execute shard
/// epochs (default 1). Purely a wall-clock knob: results are bit-identical
/// for every value. [`SnowballConfig::threads`] takes precedence when
/// non-zero.
pub const SNOWBALL_THREADS_ENV: &str = "COBI_SNOWBALL_THREADS";

/// Snowball schedule parameters.
#[derive(Debug, Clone)]
pub struct SnowballConfig {
    /// Logical parallel units the spin vector is sharded across (spin `i`
    /// belongs to shard `i % shards`); clamped to `n` per instance.
    pub shards: usize,
    /// Barrier-to-barrier epochs per restart; each shard makes one
    /// proposal per owned spin per epoch.
    pub epochs: usize,
    /// Instances with more than this many spins use focus mode (weighted
    /// candidate tournaments); at or below it, uniform sweep mode.
    pub focus_threshold: usize,
    /// Per-spin participation probability in uniform sweep mode — the
    /// stale-data symmetry breaker (see module docs).
    pub participation: f64,
    /// Initial temperature of the geometric Metropolis cooling.
    pub t_start: f64,
    /// Final temperature of the geometric Metropolis cooling.
    pub t_end: f64,
    /// Independent restarts (restart 0 honours a warm-start hint).
    pub restarts: usize,
    /// Physical worker threads for shard epochs; 0 means "read
    /// [`SNOWBALL_THREADS_ENV`], default 1". Never affects results.
    pub threads: usize,
}

impl Default for SnowballConfig {
    fn default() -> Self {
        Self {
            shards: 8,
            epochs: 160,
            focus_threshold: 24,
            participation: 0.85,
            t_start: 3.0,
            t_end: 0.05,
            restarts: 2,
            threads: 0,
        }
    }
}

impl SnowballConfig {
    /// Resolve the physical thread count: explicit config wins, then the
    /// [`SNOWBALL_THREADS_ENV`] environment knob, then 1 (sequential).
    pub fn resolved_threads(&self) -> usize {
        if self.threads > 0 {
            return self.threads;
        }
        std::env::var(SNOWBALL_THREADS_ENV)
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&t| t >= 1)
            .unwrap_or(1)
    }
}

/// Snowball-style sharded parallel-spin solver — the portfolio backend
/// that wins the large size buckets on multi-core hosts.
pub struct SnowballSolver {
    cfg: SnowballConfig,
    rng: Pcg32,
    scratch: SolveScratch,
}

impl SnowballSolver {
    /// Solver with explicit parameters.
    pub fn new(seed: u64, cfg: SnowballConfig) -> Self {
        Self {
            cfg,
            rng: Pcg32::new(seed, SNOWBALL_STREAM),
            scratch: SolveScratch::default(),
        }
    }

    /// Solver with default parameters, seeded.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, SnowballConfig::default())
    }

    /// Reset the RNG to a fresh stream keyed by `seed` (see
    /// `TabuSolver::reseed`; the device pool re-seeds per request). The
    /// scratch workspace is untouched: it carries capacity, not state.
    pub fn reseed(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed, SNOWBALL_STREAM);
    }

    /// Solve, picking the coefficient domain: integer-valued instances
    /// run the `i64` kernel, others the `f64` kernel — bit-identical
    /// results wherever both apply.
    fn solve_any(&mut self, ising: &Ising, warm: Option<&[i8]>) -> SolveResult {
        let Self { cfg, rng, scratch } = self;
        if scratch.quant.try_copy_from(ising) {
            let energy = snowball_core(&scratch.quant, cfg, rng, &mut scratch.int, warm);
            SolveResult {
                spins: scratch.int.best.clone(),
                energy,
            }
        } else {
            let energy = snowball_core(ising, cfg, rng, &mut scratch.fp, warm);
            SolveResult {
                spins: scratch.fp.best.clone(),
                energy,
            }
        }
    }

    /// Force the `f64` kernel — the reference entry the integer path is
    /// pinned against (see `TabuSolver::solve_reference_f64`). Consumes
    /// the RNG exactly like [`IsingSolver::solve`].
    pub fn solve_reference_f64(&mut self, ising: &Ising) -> SolveResult {
        let Self { cfg, rng, scratch } = self;
        let energy = snowball_core(ising, cfg, rng, &mut scratch.fp, None);
        SolveResult {
            spins: scratch.fp.best.clone(),
            energy,
        }
    }
}

/// One shard's private epoch state. Everything a shard touches lives
/// here, so epochs for different shards can run on any threads in any
/// order without observing each other.
struct ShardState<A> {
    /// Owned spin indices (`i % shards == id`), ascending.
    owned: Vec<usize>,
    /// Working copy: epoch-start snapshot plus this shard's own flips.
    spins: Vec<i8>,
    /// Local fields tracking `spins` incrementally.
    l: Vec<A>,
    /// This shard's logical update schedule.
    rng: Pcg32,
}

/// Restart wrapper over [`snowball_run`]: restart 0 starts from `warm`
/// when given (drawing no init randomness; best-so-far starts at the
/// hint, so the result is never worse than it), later restarts from
/// random configurations; best kept on strict `<` (earlier restart wins
/// exact ties). Returns the best energy; best spins land in `ks.best`.
pub(crate) fn snowball_core<K>(
    k: &K,
    cfg: &SnowballConfig,
    rng: &mut Pcg32,
    ks: &mut KernelScratch<K::Acc>,
    warm: Option<&[i8]>,
) -> f64
where
    K: SolverKernel + Sync,
    K::Acc: Send + Sync,
{
    let n = k.n();
    debug_assert!(warm.map_or(true, |h| h.len() == n), "warm-start hint length mismatch");
    ks.prepare(n);
    let mut overall: Option<K::Acc> = None;
    for r in 0..cfg.restarts.max(1) {
        match warm {
            Some(h) if r == 0 => ks.spins.copy_from_slice(h),
            _ => {
                for x in ks.spins.iter_mut() {
                    *x = if rng.bernoulli(0.5) { 1 } else { -1 };
                }
            }
        }
        // the logical schedule for this run: one seed fixes every shard's
        // proposal sequence, independent of thread count
        let schedule_seed = rng.next_u64();
        let e = snowball_run(k, cfg, schedule_seed, ks);
        if overall.map_or(true, |b| e < b) {
            overall = Some(e);
            ks.best.copy_from_slice(&ks.run_best);
        }
    }
    K::to_f64(overall.expect("restarts >= 1"))
}

/// One snowball run from the configuration in `ks.spins`, driven entirely
/// by `schedule_seed`. Best spins of the run land in `ks.run_best`.
fn snowball_run<K>(
    k: &K,
    cfg: &SnowballConfig,
    schedule_seed: u64,
    ks: &mut KernelScratch<K::Acc>,
) -> K::Acc
where
    K: SolverKernel + Sync,
    K::Acc: Send + Sync,
{
    let n = k.n();
    let shards = cfg.shards.min(n).max(1);
    let uniform = n <= cfg.focus_threshold;
    let threads = cfg.resolved_threads().min(shards).max(1);

    let mut e = k.energy_acc(&ks.spins);
    let mut best_e = e;
    ks.run_best.copy_from_slice(&ks.spins);

    let mut states: Vec<ShardState<K::Acc>> = (0..shards)
        .map(|id| ShardState {
            owned: (id..n).step_by(shards).collect(),
            spins: Vec::with_capacity(n),
            l: Vec::with_capacity(n),
            rng: Pcg32::new(
                SplitMix64::new(schedule_seed ^ id as u64).next_u64(),
                SNOWBALL_SCHEDULE_STREAM,
            ),
        })
        .collect();

    let epochs = cfg.epochs.max(1);
    let cool = (cfg.t_end / cfg.t_start).powf(1.0 / epochs as f64);
    let mut t = cfg.t_start;
    for _ in 0..epochs {
        // barrier snapshot: every shard works from the same view
        k.local_fields_into(&ks.spins, &mut ks.l);
        let snap_spins: &[i8] = &ks.spins;
        let snap_l: &[K::Acc] = &ks.l;
        if threads <= 1 {
            for st in states.iter_mut() {
                shard_epoch(k, snap_spins, snap_l, st, t, uniform, cfg.participation);
            }
        } else {
            let chunk = (shards + threads - 1) / threads;
            std::thread::scope(|scope| {
                for block in states.chunks_mut(chunk) {
                    scope.spawn(move || {
                        for st in block {
                            shard_epoch(
                                k,
                                snap_spins,
                                snap_l,
                                st,
                                t,
                                uniform,
                                cfg.participation,
                            );
                        }
                    });
                }
            });
        }
        // deterministic merge in shard order: shards own disjoint spins,
        // so the merged state is the same for every thread count
        for st in &states {
            for &i in &st.owned {
                ks.spins[i] = st.spins[i];
            }
        }
        e = k.energy_acc(&ks.spins);
        if K::lt_margin(e, best_e) {
            best_e = e;
            ks.run_best.copy_from_slice(&ks.spins);
        }
        t *= cool;
    }

    // polish: strict greedy descent from the best barrier state — no
    // randomness, lowest index wins exact delta ties (the solver-wide
    // tie-break rule)
    ks.spins.copy_from_slice(&ks.run_best);
    k.local_fields_into(&ks.spins, &mut ks.l);
    loop {
        let mut chosen: Option<(usize, K::Acc)> = None;
        for i in 0..n {
            let delta = K::flip_delta(&ks.spins, &ks.l, i);
            if K::improves(delta) && chosen.map_or(true, |(_, d)| delta < d) {
                chosen = Some((i, delta));
            }
        }
        match chosen {
            Some((i, delta)) => {
                k.apply_flip_acc(&mut ks.spins, &mut ks.l, i);
                best_e += delta;
            }
            None => break,
        }
    }
    ks.run_best.copy_from_slice(&ks.spins);
    best_e
}

/// One shard's epoch: copy the barrier snapshot into the shard's private
/// view, then propose/accept flips of owned spins per the shard's
/// schedule stream. Pure in (kernel, snapshot, shard state, temperature),
/// which is what makes thread count irrelevant to results.
fn shard_epoch<K: SolverKernel>(
    k: &K,
    snap_spins: &[i8],
    snap_l: &[K::Acc],
    st: &mut ShardState<K::Acc>,
    t: f64,
    uniform: bool,
    participation: f64,
) {
    let ShardState { owned, spins, l, rng } = st;
    spins.clear();
    spins.extend_from_slice(snap_spins);
    l.clear();
    l.extend_from_slice(snap_l);

    if uniform {
        for &i in owned.iter() {
            // participation draw first (symmetry breaker), then the
            // SA-style accept — draw order is domain-independent
            if rng.f64() >= participation {
                continue;
            }
            let delta = K::flip_delta(spins, l, i);
            if K::non_increasing(delta) || rng.f64() < (-K::to_f64(delta) / t).exp() {
                k.apply_flip_acc(spins, l, i);
            }
        }
    } else {
        for _ in 0..owned.len() {
            // tournament-of-2 focus: propose the candidate with the
            // better stale delta, exact ties to the lower spin index
            let a = owned[rng.below(owned.len() as u32) as usize];
            let b = owned[rng.below(owned.len() as u32) as usize];
            let da = K::flip_delta(spins, l, a);
            let db = K::flip_delta(spins, l, b);
            let i = if db < da {
                b
            } else if da < db {
                a
            } else {
                a.min(b)
            };
            let delta = K::flip_delta(spins, l, i);
            if K::non_increasing(delta) || rng.f64() < (-K::to_f64(delta) / t).exp() {
                k.apply_flip_acc(spins, l, i);
            }
        }
    }
}

impl IsingSolver for SnowballSolver {
    fn name(&self) -> &'static str {
        "snowball"
    }

    fn solve(&mut self, ising: &Ising) -> SolveResult {
        self.solve_any(ising, None)
    }

    fn solve_from(&mut self, ising: &Ising, init: &[i8]) -> SolveResult {
        debug_assert_eq!(init.len(), ising.n, "warm-start hint length mismatch");
        // first restart from the hint, remaining restarts cold; strict
        // `<` keeps the warm result on exact ties
        self.solve_any(ising, Some(init))
    }

    fn quant_kernel(&mut self) -> Option<&mut dyn QuantSolve> {
        Some(self)
    }
}

impl QuantSolve for SnowballSolver {
    fn solve_quant_into(&mut self, q: &QuantIsing, out: &mut Vec<i8>) -> f64 {
        let Self { cfg, rng, scratch } = self;
        let energy = snowball_core(q, cfg, rng, &mut scratch.int, None);
        out.clear();
        out.extend_from_slice(&scratch.int.best);
        energy
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cobi::testutil::quantized_glass;
    use crate::solvers::exact::ising_ground_exhaustive;

    fn random_ising(seed: u64, n: usize) -> Ising {
        let mut rng = Pcg32::seeded(seed);
        let mut ising = Ising::new(n);
        for i in 0..n {
            ising.h[i] = rng.range_f32(-1.5, 1.5);
            for j in (i + 1)..n {
                ising.set_pair(i, j, rng.range_f32(-1.0, 1.0));
            }
        }
        ising
    }

    fn with_threads(threads: usize) -> SnowballConfig {
        SnowballConfig {
            threads,
            ..Default::default()
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let ising = random_ising(10, 16);
        let a = SnowballSolver::seeded(5).solve(&ising);
        let b = SnowballSolver::seeded(5).solve(&ising);
        assert_eq!(a.spins, b.spins);
        assert_eq!(a.energy.to_bits(), b.energy.to_bits());
    }

    #[test]
    fn reported_energy_matches_spins() {
        let ising = random_ising(7, 24);
        let r = SnowballSolver::seeded(2).solve(&ising);
        assert!((ising.energy(&r.spins) - r.energy).abs() < 1e-6);
        assert!(r.spins.iter().all(|&v| v == 1 || v == -1));
    }

    #[test]
    fn descent_polish_leaves_a_local_minimum() {
        let ising = random_ising(13, 20);
        let r = SnowballSolver::seeded(4).solve(&ising);
        for i in 0..20 {
            let mut s = r.spins.clone();
            s[i] = -s[i];
            assert!(ising.energy(&s) >= r.energy - 1e-9, "flip {i} improves");
        }
    }

    #[test]
    fn near_ground_on_small_glasses() {
        // parallel MCMC + descent polish should land at (or vanishingly
        // near) the exhaustive ground state on 12-spin glasses
        for seed in 0..4 {
            let ising = random_ising(seed, 12);
            let (ge, _, _) = ising_ground_exhaustive(&ising);
            let r = SnowballSolver::seeded(seed + 40).solve(&ising);
            assert!(
                r.energy <= ge + 1e-6 + 0.05 * ge.abs(),
                "seed {seed}: snowball {} vs ground {ge}",
                r.energy
            );
        }
    }

    #[test]
    fn thread_count_is_invisible_in_results() {
        // the tentpole pin: T-thread execution is bit-identical to the
        // 1-thread sequential replay, in both selection modes
        for n in [12usize, 40] {
            let ising = random_ising(60 + n as u64, n);
            let a = SnowballSolver::new(9, with_threads(1)).solve(&ising);
            let b = SnowballSolver::new(9, with_threads(8)).solve(&ising);
            assert_eq!(a.spins, b.spins, "n {n}");
            assert_eq!(a.energy.to_bits(), b.energy.to_bits(), "n {n}");
            let c = SnowballSolver::new(9, with_threads(3)).solve(&ising);
            assert_eq!(a.spins, c.spins, "n {n} (threads=3)");
        }
    }

    #[test]
    fn integer_kernel_is_bit_identical_to_f64_on_quantized_instances() {
        // acceptance pin (snowball): identical spins, bitwise-equal
        // energy — the free-accept branch and the focus tournament decide
        // identically in both domains, so draw order matches exactly
        for seed in 0..6 {
            for n in [5, 12, 20, 33] {
                let inst = quantized_glass(4000 + seed, n);
                let a = SnowballSolver::seeded(seed).solve_reference_f64(&inst);
                let b = SnowballSolver::seeded(seed).solve(&inst);
                assert_eq!(a.spins, b.spins, "seed {seed} n {n}");
                assert_eq!(a.energy.to_bits(), b.energy.to_bits(), "seed {seed} n {n}");
            }
        }
    }

    #[test]
    fn warm_start_never_loses_the_hint() {
        // unique ground state via fields only; a warm start AT the ground
        // state must come back unchanged (strict best-so-far keeps it)
        let mut ising = Ising::new(3);
        ising.h = vec![1.0, -1.0, 1.0];
        let ground = vec![-1i8, 1, -1];
        let r = SnowballSolver::seeded(3).solve_from(&ising, &ground);
        assert_eq!(r.spins, ground);
        assert!((r.energy + 3.0).abs() < 1e-9);
    }

    #[test]
    fn warm_start_matches_core_replay() {
        let inst = quantized_glass(77, 14);
        let hint: Vec<i8> = (0..14).map(|i| if i % 3 == 0 { 1 } else { -1 }).collect();
        let mut a = SnowballSolver::seeded(4);
        let ra = {
            let SnowballSolver { cfg, rng, scratch } = &mut a;
            let e = snowball_core(&inst, cfg, rng, &mut scratch.fp, Some(&hint));
            (scratch.fp.best.clone(), e)
        };
        let rb = SnowballSolver::seeded(4).solve_from(&inst, &hint);
        // solve_from auto-selects the integer kernel on this quantized
        // instance; bit-identity makes it equal to the f64 core replay
        assert_eq!(ra.0, rb.spins);
        assert_eq!(ra.1.to_bits(), rb.energy.to_bits());
    }

    #[test]
    fn solve_quant_into_reuses_the_output_buffer() {
        let inst = quantized_glass(88, 12);
        let mut q = QuantIsing::default();
        assert!(q.try_copy_from(&inst));
        let mut out = Vec::new();
        let mut solver = SnowballSolver::seeded(6);
        let e1 = solver.solve_quant_into(&q, &mut out);
        assert_eq!(out.len(), 12);
        assert_eq!(q.energy(&out) as f64, e1);
        let r = SnowballSolver::seeded(6).solve(&inst);
        assert_eq!(r.spins, out);
        assert_eq!(r.energy.to_bits(), e1.to_bits());
    }

    #[test]
    fn focus_mode_engages_above_the_threshold() {
        // n = 40 > focus_threshold = 24: focus mode must still produce a
        // valid, deterministic configuration that beats pure chance
        let ising = random_ising(21, 40);
        let r = SnowballSolver::seeded(11).solve(&ising);
        assert_eq!(r.spins.len(), 40);
        assert!((ising.energy(&r.spins) - r.energy).abs() < 1e-6);
        // descent polish guarantees local minimality even in focus mode
        for i in 0..40 {
            let mut s = r.spins.clone();
            s[i] = -s[i];
            assert!(ising.energy(&s) >= r.energy - 1e-9, "flip {i} improves");
        }
    }

    #[test]
    fn reseed_replays_the_request_stream() {
        let ising = random_ising(31, 18);
        let mut solver = SnowballSolver::seeded(1);
        let a = solver.solve(&ising);
        solver.reseed(1);
        let b = solver.solve(&ising);
        assert_eq!(a.spins, b.spins);
        assert_eq!(a.energy.to_bits(), b.energy.to_bits());
    }
}
