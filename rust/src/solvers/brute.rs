//! Brute-force baseline: exhaustive enumeration of all cardinality-M
//! selections under the FP objective (paper Figs 7–8 baseline).
//!
//! Uses lexicographic combination stepping with an incrementally
//! maintained pair-penalty vector, so advancing to the next combination
//! costs O(n) only when the suffix rolls over and O(1) amortized
//! otherwise. For the paper's decomposed subproblems (n <= 20, M <= 10)
//! a full sweep is tens of thousands of states.

use crate::ising::EsProblem;

use super::SelectionResult;

/// Exhaustively maximize the Eq. 3 objective over all M-subsets.
pub fn solve(p: &EsProblem) -> SelectionResult {
    let n = p.n();
    let m = p.m;
    assert!(m <= n);
    assert!(
        binomial(n, m) <= 200_000_000,
        "brute-force over C({n},{m}) is infeasible; use decomposition"
    );
    let lambda = p.lambda as f64;

    // state: current combination `idx`, its objective maintained exactly
    let mut idx: Vec<usize> = (0..m).collect();
    let mut best = SelectionResult {
        selected: idx.clone(),
        objective: p.objective(&idx),
    };
    let mut cur_obj = best.objective;

    // advance combinations in lexicographic order; on each step exactly
    // one element is swapped out/in when only the last position moves —
    // the common case — and we recompute when a carry occurs.
    loop {
        // find rightmost position that can advance
        let mut pos = m;
        loop {
            if pos == 0 {
                return best;
            }
            pos -= 1;
            if idx[pos] != pos + n - m {
                break;
            }
        }
        if pos == m - 1 {
            // fast path: swap idx[m-1] -> idx[m-1]+1
            let out = idx[m - 1];
            let inn = out + 1;
            // delta = mu_in - mu_out - 2λ Σ_{j∈S\{out}} (β_in,j - β_out,j)
            let mut delta = (p.mu[inn] - p.mu[out]) as f64;
            for &j in idx[..m - 1].iter() {
                delta -=
                    2.0 * lambda * (p.beta_ij(inn, j) as f64 - p.beta_ij(out, j) as f64);
            }
            idx[m - 1] = inn;
            cur_obj += delta;
        } else {
            // carry: reset suffix and recompute (rare: O(C(n,m)/n) times)
            idx[pos] += 1;
            for k in (pos + 1)..m {
                idx[k] = idx[k - 1] + 1;
            }
            cur_obj = p.objective(&idx);
        }
        if cur_obj > best.objective {
            best.objective = cur_obj;
            best.selected = idx.clone();
        }
    }
}

/// C(n, k) with saturation (feasibility guard only).
pub fn binomial(n: usize, k: usize) -> u128 {
    if k > n {
        return 0;
    }
    let k = k.min(n - k);
    let mut acc: u128 = 1;
    for i in 0..k {
        acc = acc.saturating_mul((n - i) as u128) / (i as u128 + 1);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::exact;
    use crate::util::rng::Pcg32;

    fn random_es(seed: u64, n: usize, m: usize) -> EsProblem {
        let mut rng = Pcg32::seeded(seed);
        let mu: Vec<f32> = (0..n).map(|_| rng.range_f32(0.3, 0.95)).collect();
        let mut beta = vec![0.0f32; n * n];
        for i in 0..n {
            for j in (i + 1)..n {
                let b = rng.range_f32(0.2, 0.9);
                beta[i * n + j] = b;
                beta[j * n + i] = b;
            }
        }
        EsProblem { mu, beta, lambda: 0.6, m }
    }

    #[test]
    fn matches_exact_solver() {
        for seed in 0..6 {
            let p = random_es(seed, 14, 4);
            let b = solve(&p);
            let e = exact::solve_max(&p);
            assert!(
                (b.objective - e.objective).abs() < 1e-9,
                "seed {seed}: {} vs {}",
                b.objective,
                e.objective
            );
        }
    }

    #[test]
    fn incremental_objective_is_exact() {
        // the fast-path delta must keep cur_obj exact: check the winner's
        // objective recomputed from scratch
        let p = random_es(42, 20, 6);
        let b = solve(&p);
        assert!((p.objective(&b.selected) - b.objective).abs() < 1e-9);
        assert_eq!(b.selected.len(), 6);
    }

    #[test]
    fn binomial_values() {
        assert_eq!(binomial(20, 6), 38_760);
        assert_eq!(binomial(10, 0), 1);
        assert_eq!(binomial(5, 6), 0);
        assert_eq!(binomial(50, 6), 15_890_700);
    }

    #[test]
    #[should_panic(expected = "infeasible")]
    fn refuses_infeasible_sizes() {
        let p = random_es(1, 100, 20);
        solve(&p);
    }
}
