//! Exact solver — the Gurobi substitute (DESIGN.md §Substitutions).
//!
//! Two entry points:
//!   * [`solve_max`] / [`solve_min`]: exact extrema of the Eq. 3 objective
//!     over all cardinality-M selections, via depth-first branch-and-bound
//!     with an admissible per-candidate bound. These are the obj_max /
//!     obj_min of the Eq. 13 normalization.
//!   * [`ising_ground_exhaustive`]: exact Ising ground state (and the
//!     count of degenerate optima) for n <= 30 via Gray-code enumeration —
//!     used by the supplementary multiple-optima study and as the test
//!     oracle for the heuristic solvers.

use anyhow::{ensure, Result};

use crate::ising::{EsProblem, Ising};

use super::{IsingSolver, SelectionResult, SolveResult};

/// Internal: maximize g(S) = Σ_{i∈S} a_i + Σ_{unordered pairs in S} w_ij
/// over |S| = m, by DFS branch and bound.
///
/// Admissible bound at a node with chosen set S (|S| = t, r = m - t picks
/// left, candidates C): for each i ∈ C let
///     score_i = a_i + Σ_{j∈S} w_ij + (r-1)/2 · rowmax_i,
/// where rowmax_i = max_j max(0, w_ij). Any completed solution's gain over
/// the current g is ≤ the sum of the r largest score_i: each future pair
/// (i, j) contributes w_ij ≤ (rowmax_i + rowmax_j) / 2 once to each term.
struct Bnb<'a> {
    n: usize,
    m: usize,
    a: &'a [f64],
    /// w matrix, row-major (symmetric, zero diag).
    w: &'a [f64],
    /// rowmax_i = max_j max(0, w_ij)
    rowmax: Vec<f64>,
    /// candidate order (descending static promise)
    order: Vec<usize>,
    best: f64,
    best_set: Vec<usize>,
    nodes: u64,
}

impl<'a> Bnb<'a> {
    fn new(n: usize, m: usize, a: &'a [f64], w: &'a [f64]) -> Self {
        let rowmax: Vec<f64> = (0..n)
            .map(|i| (0..n).map(|j| w[i * n + j].max(0.0)).fold(0.0, f64::max))
            .collect();
        let mut order: Vec<usize> = (0..n).collect();
        let promise: Vec<f64> = (0..n)
            .map(|i| a[i] + (m as f64 - 1.0) / 2.0 * rowmax[i])
            .collect();
        order.sort_by(|&x, &y| promise[y].partial_cmp(&promise[x]).unwrap());
        Self {
            n,
            m,
            a,
            w,
            rowmax,
            order,
            best: f64::NEG_INFINITY,
            best_set: Vec::new(),
            nodes: 0,
        }
    }

    fn run(&mut self) {
        let mut chosen = Vec::with_capacity(self.m);
        // pair_sum[i]: Σ_{j ∈ chosen} w_ij, maintained incrementally
        let mut pair_sum = vec![0.0f64; self.n];
        self.dfs(0, 0.0, &mut chosen, &mut pair_sum);
    }

    fn dfs(&mut self, depth: usize, g: f64, chosen: &mut Vec<usize>, pair_sum: &mut Vec<f64>) {
        self.nodes += 1;
        if chosen.len() == self.m {
            if g > self.best {
                self.best = g;
                self.best_set = chosen.clone();
            }
            return;
        }
        let r = self.m - chosen.len();
        let avail = self.n - depth;
        if avail < r {
            return;
        }
        // bound: sum of the r largest candidate scores. select_nth is
        // O(c) vs the O(c log c) sort this loop used before (§Perf: this
        // node bound dominates the n=100 ground-truth computation).
        let mut scores: Vec<f64> = self.order[depth..]
            .iter()
            .map(|&i| self.a[i] + pair_sum[i] + (r as f64 - 1.0) / 2.0 * self.rowmax[i])
            .collect();
        let ub: f64 = if scores.len() > r {
            scores.select_nth_unstable_by(r - 1, |x, y| y.partial_cmp(x).unwrap());
            g + scores[..r].iter().sum::<f64>()
        } else {
            g + scores.iter().sum::<f64>()
        };
        if ub <= self.best + 1e-12 {
            return;
        }

        let cand = self.order[depth];
        // branch 1: take cand
        let gain = self.a[cand] + pair_sum[cand];
        chosen.push(cand);
        for j in 0..self.n {
            pair_sum[j] += self.w[cand * self.n + j];
        }
        self.dfs(depth + 1, g + gain, chosen, pair_sum);
        chosen.pop();
        for j in 0..self.n {
            pair_sum[j] -= self.w[cand * self.n + j];
        }
        // branch 2: skip cand
        self.dfs(depth + 1, g, chosen, pair_sum);
    }
}

fn run_extremum(p: &EsProblem, maximize: bool) -> SelectionResult {
    let n = p.n();
    assert!(p.m <= n, "summary budget {} exceeds {} sentences", p.m, n);
    let sign = if maximize { 1.0 } else { -1.0 };
    let a: Vec<f64> = p.mu.iter().map(|&x| sign * x as f64).collect();
    // unordered-pair weight: Eq. 3 counts each unordered pair twice with
    // -λ, so w_ij (counted once) = -2 λ β_ij, times the sign.
    let w: Vec<f64> = p
        .beta
        .iter()
        .map(|&b| sign * (-2.0 * p.lambda as f64 * b as f64))
        .collect();
    let mut bnb = Bnb::new(n, p.m, &a, &w);
    bnb.run();
    let mut selected = bnb.best_set.clone();
    selected.sort_unstable();
    SelectionResult {
        objective: p.objective(&selected),
        selected,
    }
}

/// Exact maximum of the Eq. 3 objective over M-subsets.
pub fn solve_max(p: &EsProblem) -> SelectionResult {
    run_extremum(p, true)
}

/// Exact minimum of the Eq. 3 objective over M-subsets.
pub fn solve_min(p: &EsProblem) -> SelectionResult {
    run_extremum(p, false)
}

/// Exact Ising ground state by Gray-code exhaustive enumeration (n <= 30).
/// Returns (best energy, one optimal configuration, number of distinct
/// optimal configurations up to the 1e-9 energy tolerance).
pub fn ising_ground_exhaustive(ising: &Ising) -> (f64, Vec<i8>, u64) {
    let n = ising.n;
    assert!(n <= 30, "exhaustive enumeration infeasible for n={n}");
    let mut s = vec![-1i8; n];
    let mut l = vec![0.0f64; n];
    super::SolverKernel::local_fields_into(ising, &s, &mut l);
    let mut e = ising.energy(&s);
    let mut best = e;
    let mut best_s = s.clone();
    let mut count: u64 = 1;
    let total: u64 = 1u64 << n;
    for k in 1..total {
        // Gray code: bit to flip is the lowest set bit index of k
        let bit = k.trailing_zeros() as usize;
        e += -2.0 * s[bit] as f64 * l[bit];
        super::apply_flip(ising, &mut s, &mut l, bit);
        if e < best - 1e-9 {
            best = e;
            best_s = s.clone();
            count = 1;
        } else if (e - best).abs() <= 1e-9 {
            count += 1;
        }
    }
    (best, best_s, count)
}

/// [`IsingSolver`] facade over [`ising_ground_exhaustive`] for tiny
/// instances — the portfolio's exact-for-tiny-N backend. On the ≤ P=20
/// window sizes the decomposition produces, 2^n enumeration is often
/// cheaper than annealing and returns a certified ground state.
/// Deterministic; ties between degenerate optima resolve to the first
/// configuration in Gray-code order (a fixed, replayable order).
pub struct ExactIsingSolver {
    /// Largest instance this solver accepts (clamped to the enumeration
    /// ceiling of [`ising_ground_exhaustive`]).
    pub max_n: usize,
}

impl ExactIsingSolver {
    /// Facade accepting instances of at most `max_n` spins.
    pub fn new(max_n: usize) -> Self {
        Self { max_n: max_n.min(30) }
    }

    /// Fallible solve: errors (instead of panicking) on oversized
    /// instances — the portfolio routes through this.
    pub fn solve_checked(&self, ising: &Ising) -> Result<SolveResult> {
        ensure!(
            ising.n <= self.max_n,
            "instance has {} spins; exact enumeration is capped at {}",
            ising.n,
            self.max_n
        );
        let (energy, spins, _) = ising_ground_exhaustive(ising);
        Ok(SolveResult { spins, energy })
    }
}

impl IsingSolver for ExactIsingSolver {
    fn name(&self) -> &'static str {
        "exact"
    }

    fn solve(&mut self, ising: &Ising) -> SolveResult {
        self.solve_checked(ising)
            .expect("instance too large for the exact backend (route elsewhere)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn random_es(rng: &mut Pcg32, n: usize, m: usize) -> EsProblem {
        let mu: Vec<f32> = (0..n).map(|_| rng.range_f32(0.3, 0.95)).collect();
        let mut beta = vec![0.0f32; n * n];
        for i in 0..n {
            for j in (i + 1)..n {
                let b = rng.range_f32(0.2, 0.9);
                beta[i * n + j] = b;
                beta[j * n + i] = b;
            }
        }
        EsProblem { mu, beta, lambda: 0.6, m }
    }

    fn enumerate_extrema(p: &EsProblem) -> (f64, f64) {
        // plain recursive enumeration oracle
        fn rec(p: &EsProblem, start: usize, left: usize, cur: &mut Vec<usize>,
               out: &mut (f64, f64)) {
            if left == 0 {
                let o = p.objective(cur);
                out.0 = out.0.min(o);
                out.1 = out.1.max(o);
                return;
            }
            for i in start..=(p.n() - left) {
                cur.push(i);
                rec(p, i + 1, left - 1, cur, out);
                cur.pop();
            }
        }
        let mut out = (f64::INFINITY, f64::NEG_INFINITY);
        rec(p, 0, p.m, &mut Vec::new(), &mut out);
        out
    }

    #[test]
    fn bnb_matches_enumeration() {
        let mut rng = Pcg32::seeded(21);
        for trial in 0..8 {
            let n = 8 + rng.below(6) as usize;
            let m = 2 + rng.below(4) as usize;
            let p = random_es(&mut rng, n, m);
            let (lo, hi) = enumerate_extrema(&p);
            let max = solve_max(&p);
            let min = solve_min(&p);
            assert!((max.objective - hi).abs() < 1e-9, "trial {trial}: max");
            assert!((min.objective - lo).abs() < 1e-9, "trial {trial}: min");
            assert_eq!(max.selected.len(), m);
            assert_eq!(min.selected.len(), m);
        }
    }

    #[test]
    fn bnb_handles_negative_beta() {
        // admissibility with mixed-sign pair weights
        let mut rng = Pcg32::seeded(22);
        let mut p = random_es(&mut rng, 10, 3);
        for i in 0..10 {
            for j in (i + 1)..10 {
                if rng.bernoulli(0.3) {
                    let v = -rng.range_f32(0.0, 0.5);
                    p.beta[i * 10 + j] = v;
                    p.beta[j * 10 + i] = v;
                }
            }
        }
        let (lo, hi) = enumerate_extrema(&p);
        assert!((solve_max(&p).objective - hi).abs() < 1e-9);
        assert!((solve_min(&p).objective - lo).abs() < 1e-9);
    }

    #[test]
    fn bnb_m_equals_n_selects_everything() {
        let mut rng = Pcg32::seeded(23);
        let p = random_es(&mut rng, 6, 6);
        let r = solve_max(&p);
        assert_eq!(r.selected, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn exhaustive_ground_state_small() {
        // cross-check against direct enumeration on 10 spins
        let mut rng = Pcg32::seeded(24);
        let mut ising = Ising::new(10);
        for i in 0..10 {
            ising.h[i] = rng.range_f32(-1.0, 1.0);
            for j in (i + 1)..10 {
                ising.set_pair(i, j, rng.range_f32(-1.0, 1.0));
            }
        }
        let (e, s, _count) = ising_ground_exhaustive(&ising);
        assert!((ising.energy(&s) - e).abs() < 1e-9);
        let mut brute = f64::INFINITY;
        for bits in 0..(1u32 << 10) {
            let s: Vec<i8> = (0..10)
                .map(|i| if (bits >> i) & 1 == 1 { 1 } else { -1 })
                .collect();
            brute = brute.min(ising.energy(&s));
        }
        assert!((e - brute).abs() < 1e-9);
    }

    #[test]
    fn degenerate_optima_counted() {
        // h = 0, J = 0: every configuration is optimal -> count = 2^n
        let ising = Ising::new(4);
        let (e, _s, count) = ising_ground_exhaustive(&ising);
        assert_eq!(e, 0.0);
        assert_eq!(count, 16);
    }

    #[test]
    fn ising_solver_facade_matches_exhaustive_enumeration() {
        let mut rng = Pcg32::seeded(26);
        let mut ising = Ising::new(12);
        for i in 0..12 {
            ising.h[i] = rng.range_f32(-1.0, 1.0);
            for j in (i + 1)..12 {
                ising.set_pair(i, j, rng.range_f32(-1.0, 1.0));
            }
        }
        let mut solver = ExactIsingSolver::new(16);
        let r = solver.solve(&ising);
        let (ge, gs, _) = ising_ground_exhaustive(&ising);
        assert_eq!(r.spins, gs);
        assert!((r.energy - ge).abs() < 1e-12);
        // oversized instances error instead of panicking
        assert!(ExactIsingSolver::new(8).solve_checked(&ising).is_err());
        // the ceiling clamps to the enumeration limit
        assert_eq!(ExactIsingSolver::new(64).max_n, 30);
    }

    #[test]
    fn bnb_scales_to_100_sentences() {
        // xsum-scale bound check: must terminate quickly and agree with
        // a greedy lower bound on feasibility
        let mut rng = Pcg32::seeded(25);
        let p = random_es(&mut rng, 100, 6);
        let max = solve_max(&p);
        assert_eq!(max.selected.len(), 6);
        let min = solve_min(&p);
        assert!(min.objective <= max.objective);
    }
}
