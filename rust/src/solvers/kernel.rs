//! `SolverKernel`: one set of Tabu/SA/greedy inner loops, two coefficient
//! domains.
//!
//! The quantized solve path used to run integer-valued Hamiltonians
//! through dense `f32` matrices with `f64` scalar loops. This module lets
//! each heuristic solver run the SAME control flow over either domain:
//!
//! * [`Ising`] — `f32` coefficients, `f64` accumulators, the original
//!   kernels. Tie comparisons use the [`TIE_EPS`] margin.
//! * [`QuantIsing`] — `i32`/`i16` coefficients, `i64` accumulators. Ties
//!   are **exact integer equality**; no epsilon exists on this path.
//!
//! The two rules coincide on quantized instances (small integers are
//! exact in `f64`, and for integers `a < b - 1e-12` ⟺ `a < b`), so the
//! integer kernels return **bit-identical spins and energies** to the
//! `f64` kernels — pinned by per-solver equivalence tests. That identity
//! is what lets [`TabuSolver`](super::tabu::TabuSolver),
//! [`SaSolver`](super::sa::SaSolver) and
//! [`GreedyDescent`](super::greedy::GreedyDescent) switch to the integer
//! domain transparently whenever an instance is integer-valued, without
//! changing one summary byte.
//!
//! [`SolveScratch`] is the per-solver workspace (spins, local fields,
//! tabu tenures, the integer-instance buffer): every buffer is resized in
//! place, so a long-lived solver — one per pool device, portfolio backend
//! or pipeline — does zero heap allocation per solve in steady state
//! (DESIGN.md decision #13 records why solvers own it, not the pool).

use crate::ising::{Ising, QuantIsing};

use super::TIE_EPS;

/// A coefficient domain the heuristic inner loops can run on: provides
/// energies, incremental local fields and the domain's tie semantics.
/// Implemented by [`Ising`] (`f64` accumulators, `TIE_EPS` ties) and
/// [`QuantIsing`] (`i64` accumulators, exact ties) — see module docs.
pub trait SolverKernel {
    /// Energy / local-field / move-delta accumulator. `Default` is the
    /// zero value (what `KernelScratch::prepare` fills buffers with).
    type Acc: Copy
        + Default
        + PartialOrd
        + std::ops::Add<Output = Self::Acc>
        + std::ops::AddAssign;

    /// Number of spins in the bound instance.
    fn n(&self) -> usize;

    /// Full energy of `s` (ordered-pair convention).
    fn energy_acc(&self, s: &[i8]) -> Self::Acc;

    /// Fill `l` with local fields L_i = h_i + 2 Σ_j J_ij s_j.
    fn local_fields_into(&self, s: &[i8], l: &mut [Self::Acc]);

    /// Flip spin `k` and update all local fields incrementally (O(n)).
    fn apply_flip_acc(&self, s: &mut [i8], l: &mut [Self::Acc], k: usize);

    /// Energy delta of flipping spin `i`: ΔE = -2 s_i L_i.
    fn flip_delta(s: &[i8], l: &[Self::Acc], i: usize) -> Self::Acc;

    /// `a` beats `b` by more than a tie margin (the "strictly better"
    /// test for best-so-far and aspiration): `a < b - TIE_EPS` on the
    /// f64 domain, exact `a < b` on the integer domain.
    fn lt_margin(a: Self::Acc, b: Self::Acc) -> bool;

    /// Strictly-improving move: `delta < -TIE_EPS` / `delta < 0`.
    fn improves(delta: Self::Acc) -> bool;

    /// Downhill-or-flat move (the SA free-accept test): `delta <= 0`.
    fn non_increasing(delta: Self::Acc) -> bool;

    /// Exact on every reachable value (integer accumulators stay far
    /// below 2^53 — see `ising::quant_model` headroom analysis).
    fn to_f64(a: Self::Acc) -> f64;

    /// Field-aligned cold start: s_i = -sign(h_i), ties to +1 (the
    /// greedy-descent cold init).
    fn cold_init(&self, s: &mut [i8]);
}

impl SolverKernel for Ising {
    type Acc = f64;

    #[inline]
    fn n(&self) -> usize {
        self.n
    }

    fn energy_acc(&self, s: &[i8]) -> f64 {
        self.energy(s)
    }

    fn local_fields_into(&self, s: &[i8], l: &mut [f64]) {
        let n = self.n;
        for i in 0..n {
            let row = &self.j[i * n..(i + 1) * n];
            let mut acc = 0.0f64;
            for j in 0..n {
                acc += row[j] as f64 * s[j] as f64;
            }
            l[i] = self.h[i] as f64 + 2.0 * acc;
        }
    }

    fn apply_flip_acc(&self, s: &mut [i8], l: &mut [f64], k: usize) {
        super::apply_flip(self, s, l, k);
    }

    #[inline]
    fn flip_delta(s: &[i8], l: &[f64], i: usize) -> f64 {
        -2.0 * s[i] as f64 * l[i]
    }

    #[inline]
    fn lt_margin(a: f64, b: f64) -> bool {
        a < b - TIE_EPS
    }

    #[inline]
    fn improves(delta: f64) -> bool {
        delta < -TIE_EPS
    }

    #[inline]
    fn non_increasing(delta: f64) -> bool {
        delta <= 0.0
    }

    #[inline]
    fn to_f64(a: f64) -> f64 {
        a
    }

    fn cold_init(&self, s: &mut [i8]) {
        for (x, &h) in s.iter_mut().zip(&self.h) {
            *x = if h > 0.0 { -1 } else { 1 };
        }
    }
}

impl SolverKernel for QuantIsing {
    type Acc = i64;

    #[inline]
    fn n(&self) -> usize {
        self.n
    }

    fn energy_acc(&self, s: &[i8]) -> i64 {
        self.energy(s)
    }

    fn local_fields_into(&self, s: &[i8], l: &mut [i64]) {
        let n = self.n;
        for i in 0..n {
            let row = &self.j[i * n..(i + 1) * n];
            let mut acc = 0i64;
            for j in 0..n {
                acc += row[j] as i64 * s[j] as i64;
            }
            l[i] = self.h[i] as i64 + 2 * acc;
        }
    }

    fn apply_flip_acc(&self, s: &mut [i8], l: &mut [i64], k: usize) {
        s[k] = -s[k];
        let new_sk = s[k] as i64;
        let n = self.n;
        let row = &self.j[k * n..(k + 1) * n];
        for i in 0..n {
            // row[k] == 0 (zero diagonal) so including i == k is harmless
            l[i] += 4 * row[i] as i64 * new_sk;
        }
    }

    #[inline]
    fn flip_delta(s: &[i8], l: &[i64], i: usize) -> i64 {
        -2 * s[i] as i64 * l[i]
    }

    #[inline]
    fn lt_margin(a: i64, b: i64) -> bool {
        a < b
    }

    #[inline]
    fn improves(delta: i64) -> bool {
        delta < 0
    }

    #[inline]
    fn non_increasing(delta: i64) -> bool {
        delta <= 0
    }

    #[inline]
    fn to_f64(a: i64) -> f64 {
        a as f64
    }

    fn cold_init(&self, s: &mut [i8]) {
        for (x, &h) in s.iter_mut().zip(&self.h) {
            *x = if h > 0 { -1 } else { 1 };
        }
    }
}

/// Reusable working memory for one coefficient domain: current spins, the
/// best configuration of the current run, the best across runs, local
/// fields and tabu tenures. `prepare` resizes everything in place.
#[derive(Debug, Clone, Default)]
pub struct KernelScratch<A> {
    pub(crate) spins: Vec<i8>,
    pub(crate) run_best: Vec<i8>,
    pub(crate) best: Vec<i8>,
    pub(crate) l: Vec<A>,
    pub(crate) tabu_until: Vec<usize>,
}

impl<A: Copy + Default> KernelScratch<A> {
    pub(crate) fn prepare(&mut self, n: usize) {
        self.spins.clear();
        self.spins.resize(n, 0);
        self.run_best.clear();
        self.run_best.resize(n, 0);
        self.best.clear();
        self.best.resize(n, 0);
        self.l.clear();
        self.l.resize(n, A::default());
        // tabu_until is (re)zeroed per run by the tabu core
    }
}

/// The per-solver workspace threaded through every hot solve: one
/// [`KernelScratch`] per domain plus the integer-instance buffer that
/// `try_copy_from` / `quantize_into` fill. Owned by the solver (Tabu, SA,
/// greedy descent) so that the long-lived solver instances hosted by pool
/// devices, portfolios and pipelines reuse it across requests — steady
/// state does zero hot-path allocation.
#[derive(Debug, Clone, Default)]
pub struct SolveScratch {
    pub(crate) fp: KernelScratch<f64>,
    pub(crate) int: KernelScratch<i64>,
    pub(crate) quant: QuantIsing,
}

/// A solver that can run its inner loop directly on an integer-domain
/// instance, writing the result into caller-owned buffers — the
/// allocation-free entry the refinement fast path uses. Returns the best
/// energy (an exact integer, reported as `f64` for [`SolveResult`]
/// compatibility); `out` is cleared and filled with the best spins.
///
/// [`SolveResult`]: super::SolveResult
pub trait QuantSolve {
    /// Solve `q` on the integer kernel, writing the best spins into `out`.
    fn solve_quant_into(&mut self, q: &QuantIsing, out: &mut Vec<i8>) -> f64;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn int_glass(seed: u64, n: usize) -> QuantIsing {
        let mut rng = Pcg32::seeded(seed);
        let mut q = QuantIsing::new(n);
        for i in 0..n {
            q.h[i] = rng.below(29) as i32 - 14;
            for j in (i + 1)..n {
                q.set_pair(i, j, (rng.below(29) as i32 - 14) as i16);
            }
        }
        q
    }

    #[test]
    fn integer_local_fields_track_flips_exactly() {
        let q = int_glass(5, 16);
        let f = q.to_ising();
        let mut rng = Pcg32::seeded(6);
        let mut s: Vec<i8> = (0..16)
            .map(|_| if rng.bernoulli(0.5) { 1 } else { -1 })
            .collect();
        let mut li = vec![0i64; 16];
        let mut lf = vec![0.0f64; 16];
        q.local_fields_into(&s, &mut li);
        <Ising as SolverKernel>::local_fields_into(&f, &s, &mut lf);
        for _ in 0..40 {
            let k = rng.below(16) as usize;
            let di = <QuantIsing as SolverKernel>::flip_delta(&s, &li, k);
            let df = <Ising as SolverKernel>::flip_delta(&s, &lf, k);
            assert_eq!(di as f64, df);
            let mut s2 = s.clone();
            q.apply_flip_acc(&mut s, &mut li, k);
            f.apply_flip_acc(&mut s2, &mut lf, k);
            assert_eq!(s, s2);
            for i in 0..16 {
                assert_eq!(li[i] as f64, lf[i], "field {i} diverged");
            }
            // incremental matches from-scratch
            let mut fresh = vec![0i64; 16];
            q.local_fields_into(&s, &mut fresh);
            assert_eq!(fresh, li);
        }
    }

    #[test]
    fn tie_semantics_agree_on_integers() {
        // the module-level claim in miniature: the f64 margin rule and
        // the exact integer rule decide identically on integer data
        for a in -3i64..=3 {
            for b in -3i64..=3 {
                assert_eq!(
                    <QuantIsing as SolverKernel>::lt_margin(a, b),
                    <Ising as SolverKernel>::lt_margin(a as f64, b as f64),
                    "lt_margin({a}, {b})"
                );
            }
            assert_eq!(
                <QuantIsing as SolverKernel>::improves(a),
                <Ising as SolverKernel>::improves(a as f64),
                "improves({a})"
            );
            assert_eq!(
                <QuantIsing as SolverKernel>::non_increasing(a),
                <Ising as SolverKernel>::non_increasing(a as f64),
                "non_increasing({a})"
            );
        }
    }

    #[test]
    fn cold_init_agrees_across_domains() {
        let q = int_glass(9, 12);
        let f = q.to_ising();
        let mut si = vec![0i8; 12];
        let mut sf = vec![0i8; 12];
        q.cold_init(&mut si);
        f.cold_init(&mut sf);
        assert_eq!(si, sf);
        // zero field maps to +1 in both domains
        let z = QuantIsing::new(3);
        let mut s = vec![0i8; 3];
        z.cold_init(&mut s);
        assert_eq!(s, vec![1, 1, 1]);
    }
}
