//! Native coupled-oscillator (COBI) dynamics — the pure-Rust mirror of the
//! L1 Pallas kernel + L2 anneal graph (python/compile/kernels/oscillator.py,
//! model.cobi_anneal).
//!
//! Semantics match the artifact exactly (same normalization, SHIL ramp,
//! Euler update and readout, all in f32); floating-point trajectories may
//! diverge from XLA over hundreds of chaotic steps, so cross-backend tests
//! compare solution-quality statistics, not bits. This backend exists to
//! (a) cross-validate the HLO artifact, (b) run COBI experiments cheaply
//! inside `cargo test`/`cargo bench`, and (c) serve as the reference for
//! the §Perf L3 optimization of the hot loop.

use crate::ising::Ising;
use crate::util::rng::Pcg32;

use super::{IsingSolver, SolveResult};

/// Oscillator-integrator parameters (native mirror of the HLO anneal).
#[derive(Debug, Clone)]
pub struct OscillatorConfig {
    /// Euler steps per solve (matches model.ANNEAL_STEPS for the artifact).
    pub steps: usize,
    /// Coupling gain k_c.
    pub k_coupling: f32,
    /// SHIL strength ramps linearly 0 -> k_shil_max.
    pub k_shil_max: f32,
    /// Euler dt.
    pub dt: f32,
    /// Per-step phase-noise amplitude (the run-to-run variability knob).
    pub noise_amp: f32,
}

impl Default for OscillatorConfig {
    fn default() -> Self {
        Self {
            steps: 256,
            k_coupling: 2.0,
            k_shil_max: 1.5,
            dt: 0.05,
            noise_amp: 0.10,
        }
    }
}

/// One Euler step of the Kuramoto+SHIL dynamics, f32, mirroring the Pallas
/// kernel: dphi = k_c (s.*(J c) - c.*(J s) + h.*s) - k_s sin(2 phi) + noise.
/// `jc`/`js`/`sin_buf`/`cos_buf` are caller-provided scratch to keep the
/// hot loop allocation-free.
#[allow(clippy::too_many_arguments)]
pub fn step(
    phase: &mut [f32],
    j: &[f32],
    h: &[f32],
    k_c: f32,
    k_s: f32,
    dt: f32,
    noise: &[f32],
    sin_buf: &mut [f32],
    cos_buf: &mut [f32],
    jc: &mut [f32],
    js: &mut [f32],
) {
    let n = phase.len();
    for i in 0..n {
        let (s, c) = phase[i].sin_cos();
        sin_buf[i] = s;
        cos_buf[i] = c;
    }
    // two dense mat-vecs fused into one row traversal (§Perf: J is read
    // once per step instead of twice). Four independent accumulator lanes
    // per output let LLVM vectorize despite strict float semantics —
    // summation order differs from the naive loop, which is fine: the
    // native backend's contract with the HLO artifact is statistical, not
    // bitwise (see module docs).
    for i in 0..n {
        let row = &j[i * n..(i + 1) * n];
        let chunks = n / 4;
        let (mut c0, mut c1, mut c2, mut c3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for k in 0..chunks {
            let b = 4 * k;
            c0 += row[b] * cos_buf[b];
            c1 += row[b + 1] * cos_buf[b + 1];
            c2 += row[b + 2] * cos_buf[b + 2];
            c3 += row[b + 3] * cos_buf[b + 3];
            s0 += row[b] * sin_buf[b];
            s1 += row[b + 1] * sin_buf[b + 1];
            s2 += row[b + 2] * sin_buf[b + 2];
            s3 += row[b + 3] * sin_buf[b + 3];
        }
        let mut acc_c = (c0 + c1) + (c2 + c3);
        let mut acc_s = (s0 + s1) + (s2 + s3);
        for k in (4 * chunks)..n {
            acc_c += row[k] * cos_buf[k];
            acc_s += row[k] * sin_buf[k];
        }
        jc[i] = acc_c;
        js[i] = acc_s;
    }
    for i in 0..n {
        let (s, c) = (sin_buf[i], cos_buf[i]);
        let coupling = s * jc[i] - c * js[i];
        let local = h[i] * s;
        // sin(2 phi) = 2 sin(phi) cos(phi): reuses the step's sin/cos
        // instead of a third transcendental (§Perf)
        let dphi = k_c * (coupling + local) - k_s * (2.0 * s * c) + noise[i];
        let mut out = phase[i] + dt * dphi;
        // wrap to (-pi, pi]: dphi*dt is small, so a conditional fixup is
        // exact here and much cheaper than rem_euclid (§Perf). Matches
        // jnp.mod(out + pi, 2 pi) - pi on the same branch outcomes.
        if out > std::f32::consts::PI {
            out -= 2.0 * std::f32::consts::PI;
        } else if out <= -std::f32::consts::PI {
            out += 2.0 * std::f32::consts::PI;
        }
        phase[i] = out;
    }
}

/// Full anneal with externally supplied initial phases and per-step noise
/// (the exact artifact interface): returns spins s_i = sign(cos phi_i).
pub fn anneal(
    ising: &Ising,
    cfg: &OscillatorConfig,
    phase0: &[f32],
    noise: &[f32], // steps * n, row-major
) -> Vec<i8> {
    let n = ising.n;
    assert_eq!(phase0.len(), n);
    assert_eq!(noise.len(), cfg.steps * n);

    // scale-normalize like the artifact (argmin-invariant)
    let scale = ising.max_abs().max(1e-12);
    let j: Vec<f32> = ising.j.iter().map(|v| v / scale).collect();
    let h: Vec<f32> = ising.h.iter().map(|v| v / scale).collect();

    let mut phase = phase0.to_vec();
    let mut sin_buf = vec![0.0f32; n];
    let mut cos_buf = vec![0.0f32; n];
    let mut jc = vec![0.0f32; n];
    let mut js = vec![0.0f32; n];
    for t in 0..cfg.steps {
        let k_s = (t as f32 / cfg.steps as f32) * cfg.k_shil_max;
        step(
            &mut phase,
            &j,
            &h,
            cfg.k_coupling,
            k_s,
            cfg.dt,
            &noise[t * n..(t + 1) * n],
            &mut sin_buf,
            &mut cos_buf,
            &mut jc,
            &mut js,
        );
    }
    phase
        .iter()
        .map(|&p| if p.cos() >= 0.0 { 1i8 } else { -1i8 })
        .collect()
}

/// Self-contained solver: draws phase0 ~ U(-pi, pi) and noise ~ N(0, amp)
/// from its seeded RNG per solve.
pub struct OscillatorSolver {
    /// Integrator parameters.
    pub cfg: OscillatorConfig,
    rng: Pcg32,
}

impl OscillatorSolver {
    /// Solver with an explicit config.
    pub fn new(seed: u64, cfg: OscillatorConfig) -> Self {
        Self {
            cfg,
            rng: Pcg32::new(seed, 0x05C1),
        }
    }

    /// Solver with the default config, seeded.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, OscillatorConfig::default())
    }

    /// Draw the (phase0, noise) inputs for one run — exposed so the HLO
    /// backend can feed identical inputs to the artifact.
    pub fn draw_inputs(&mut self, n: usize) -> (Vec<f32>, Vec<f32>) {
        let mut phase0 = vec![0.0f32; n];
        for p in phase0.iter_mut() {
            *p = self.rng.range_f32(-std::f32::consts::PI, std::f32::consts::PI);
        }
        let mut noise = vec![0.0f32; self.cfg.steps * n];
        self.rng.fill_normal(&mut noise, self.cfg.noise_amp);
        (phase0, noise)
    }
}

impl IsingSolver for OscillatorSolver {
    fn name(&self) -> &'static str {
        "oscillator"
    }

    fn solve(&mut self, ising: &Ising) -> SolveResult {
        let (phase0, noise) = self.draw_inputs(ising.n);
        let spins = anneal(ising, &self.cfg, &phase0, &noise);
        let energy = ising.energy(&spins);
        SolveResult { spins, energy }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::exact::ising_ground_exhaustive;

    fn glass(seed: u64, n: usize) -> Ising {
        let mut rng = Pcg32::seeded(seed);
        let mut ising = Ising::new(n);
        for i in 0..n {
            ising.h[i] = rng.range_f32(-1.0, 1.0);
            for j in (i + 1)..n {
                ising.set_pair(i, j, rng.range_f32(-1.0, 1.0));
            }
        }
        ising
    }

    #[test]
    fn output_is_binary_and_energy_consistent() {
        let ising = glass(1, 20);
        let r = OscillatorSolver::seeded(2).solve(&ising);
        assert!(r.spins.iter().all(|&s| s == 1 || s == -1));
        assert!((ising.energy(&r.spins) - r.energy).abs() < 1e-6);
    }

    #[test]
    fn retry_regime_hit_rate() {
        // mirror of python test_ground_state_hit_rate_in_retry_regime:
        // mean ground-state probability over 10-spin glasses in (0.25,0.98)
        let mut hits = 0usize;
        let mut runs = 0usize;
        for inst in [1u64, 2, 3, 42] {
            let ising = glass(inst, 10);
            let (ge, _, _) = ising_ground_exhaustive(&ising);
            let mut solver = OscillatorSolver::seeded(inst * 31);
            for _ in 0..10 {
                let r = solver.solve(&ising);
                hits += ((r.energy - ge).abs() < 1e-3) as usize;
                runs += 1;
            }
        }
        let rate = hits as f64 / runs as f64;
        assert!((0.25..=0.98).contains(&rate), "hit rate {rate}");
    }

    #[test]
    fn ferromagnet_aligns() {
        let n = 8;
        let mut ising = Ising::new(n);
        for i in 0..n {
            for j in (i + 1)..n {
                ising.set_pair(i, j, -2.0);
            }
        }
        let mut solver = OscillatorSolver::seeded(7);
        let mut aligned = 0;
        for _ in 0..6 {
            let r = solver.solve(&ising);
            let sum: i32 = r.spins.iter().map(|&s| s as i32).sum();
            aligned += (sum.unsigned_abs() as usize == n) as usize;
        }
        assert!(aligned >= 5, "aligned only {aligned}/6");
    }

    #[test]
    fn field_polarizes() {
        let mut ising = Ising::new(6);
        ising.h = vec![-3.0, -3.0, -3.0, 3.0, 3.0, 3.0];
        let r = OscillatorSolver::seeded(3).solve(&ising);
        assert_eq!(&r.spins[..3], &[1, 1, 1]);
        assert_eq!(&r.spins[3..], &[-1, -1, -1]);
    }

    #[test]
    fn scale_invariance() {
        // same noise stream + scaled instance -> identical spins
        let ising = glass(5, 12);
        let mut scaled = ising.clone();
        for v in scaled.h.iter_mut() {
            *v *= 37.0;
        }
        for v in scaled.j.iter_mut() {
            *v *= 37.0;
        }
        let cfg = OscillatorConfig::default();
        let (phase0, noise) = OscillatorSolver::seeded(9).draw_inputs(12);
        let a = anneal(&ising, &cfg, &phase0, &noise);
        let b = anneal(&scaled, &cfg, &phase0, &noise);
        assert_eq!(a, b);
    }

    #[test]
    fn deterministic_given_seed() {
        let ising = glass(6, 16);
        assert_eq!(
            OscillatorSolver::seeded(11).solve(&ising).spins,
            OscillatorSolver::seeded(11).solve(&ising).spins
        );
    }
}
