//! Pinned request corpora for the non-ES k-of-n workloads.
//!
//! Each corpus is a frozen list of [`WorkloadRequest`]s — an id plus the
//! body lines exactly as a `::WORKLOAD <name>::` client would send them —
//! so golden fixtures, conformance suites and experiments all iterate
//! byte-identical inputs:
//!
//! | workload     | requests | shape                                  |
//! |--------------|----------|----------------------------------------|
//! | `retrieval`  | 12       | 1 query line + 12 candidate passages   |
//! | `dispersion` | 8        | 1 spec line (`n=.. k=.. seed=..`)      |
//!
//! Retrieval passages come from the same synthetic news generator as the
//! benchmark sets (a fresh seed stream, so they never alias a benchmark
//! document); dispersion rows span the calibrator's instance-size range.

use anyhow::{bail, Result};

use super::synthetic::{Generator, GeneratorConfig};

/// One pinned workload request: body lines as a TCP client sends them
/// (see [`crate::service::tcp::WORKLOAD_PREFIX`]).
#[derive(Debug, Clone)]
pub struct WorkloadRequest {
    /// Stable request id (per-request seeds key off it).
    pub id: String,
    /// Body lines: candidates, preceded by the query (retrieval) or a
    /// single instance spec (dispersion).
    pub lines: Vec<String>,
}

/// Deterministic seed per corpus — a distinct stream constant from the
/// benchmark sets', so workload corpora never alias benchmark documents.
fn corpus_seed(name: &str) -> u64 {
    crate::text::tokenize::fnv1a(name.as_bytes()) ^ 0xC0B1_E5E5_0000_0002
}

/// The pinned diverse-retrieval corpus: 12 requests, each one query line
/// followed by 12 candidate passages.
pub fn retrieval_requests() -> Vec<WorkloadRequest> {
    let cfg = GeneratorConfig {
        topics_per_doc: 3,
        coherence: 0.55,
        key_facts: 3,
    };
    let mut g = Generator::new(corpus_seed("retrieval_12"), cfg);
    g.documents("retrieval", 12, 13)
        .into_iter()
        .map(|d| WorkloadRequest {
            id: d.id,
            lines: d.sentences,
        })
        .collect()
}

/// The pinned facility-dispersion table: 8 instance specs spanning the
/// calibrator's problem-size range.
pub fn dispersion_requests() -> Vec<WorkloadRequest> {
    const ROWS: &[(usize, usize, u64)] = &[
        (8, 2, 1),
        (10, 3, 2),
        (12, 3, 3),
        (14, 4, 4),
        (16, 4, 5),
        (20, 5, 6),
        (24, 6, 7),
        (32, 8, 8),
    ];
    ROWS.iter()
        .map(|&(n, k, seed)| WorkloadRequest {
            id: format!("dispersion-{n:02}-{k:02}"),
            lines: vec![format!("n={n} k={k} seed={seed}")],
        })
        .collect()
}

/// The pinned request corpus for a registered non-ES workload. (ES runs
/// the benchmark sets through the legacy pipeline instead — see
/// [`super::benchmark_set`].)
pub fn workload_requests(workload: &str) -> Result<Vec<WorkloadRequest>> {
    match workload {
        "retrieval" => Ok(retrieval_requests()),
        "dispersion" => Ok(dispersion_requests()),
        _ => bail!("no pinned request corpus for workload '{workload}' (try retrieval, dispersion)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retrieval_corpus_shape_and_reproducibility() {
        let a = retrieval_requests();
        assert_eq!(a.len(), 12);
        for r in &a {
            assert_eq!(r.lines.len(), 13, "{}: query + 12 passages", r.id);
        }
        let b = retrieval_requests();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.lines, y.lines);
        }
    }

    #[test]
    fn retrieval_corpus_does_not_alias_benchmark_documents() {
        let set = super::super::benchmark_set("bench_10").unwrap();
        let reqs = retrieval_requests();
        assert_ne!(set.documents[0].sentences[0], reqs[0].lines[0]);
    }

    #[test]
    fn dispersion_table_parses_into_problems() {
        use crate::workload::dispersion::{DispersionProblem, DispersionSpec};
        use crate::workload::KOfNProblem;
        let reqs = dispersion_requests();
        assert_eq!(reqs.len(), 8);
        let cfg = crate::config::WorkloadConfig::default();
        for r in &reqs {
            let spec = DispersionSpec::parse(&r.lines[0], &cfg).unwrap();
            let p = DispersionProblem::generate(&r.id, spec.seed, spec.n, spec.k).unwrap();
            assert!(p.k() >= 2 && p.k() < p.candidates().len(), "{}", r.id);
        }
    }

    #[test]
    fn unknown_workload_corpus_is_error() {
        assert!(workload_requests("es").is_err());
        assert!(workload_requests("nope").is_err());
    }
}
