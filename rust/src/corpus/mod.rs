//! Corpus substrate: document types + synthetic benchmark generation.
//!
//! The paper evaluates on CNN/DailyMail (20- and 50-sentence paragraphs)
//! and XSum (100-sentence paragraphs). Those datasets are not available in
//! this offline environment, so `synthetic` generates topic-structured
//! news-style documents whose (mu, beta) geometry matches what the
//! pipeline actually consumes (DESIGN.md §Substitutions), and `benchmark`
//! pins the seeded benchmark sets used by every experiment. Beyond the
//! paper-sized sets, [`Generator::long_document`] builds
//! thousands-of-sentences archival pages for the tree strategy and
//! [`Generator::feed`] ragged-chunked arrival streams for
//! `SUMMARIZE_STREAM` workloads.

pub mod benchmark;
pub mod synthetic;
pub mod workloads;

pub use benchmark::{benchmark_set, BenchmarkSet};
pub use synthetic::{Generator, GeneratorConfig, StreamingFeed};
pub use workloads::{workload_requests, WorkloadRequest};

/// A document: ordered sentences plus a construction-time reference
/// summary (indices of the generator's designated key-fact sentences),
/// used for ROUGE-style quality reporting.
#[derive(Debug, Clone)]
pub struct Document {
    /// Stable document id (per-document seeds key off it).
    pub id: String,
    /// Ordered sentences.
    pub sentences: Vec<String>,
    /// Indices (into `sentences`) of the reference key-fact sentences.
    pub reference: Vec<usize>,
}

impl Document {
    /// Sentences joined into one string.
    pub fn text(&self) -> String {
        self.sentences.join(" ")
    }

    /// Sentence count.
    pub fn len(&self) -> usize {
        self.sentences.len()
    }

    /// True when the document has no sentences.
    pub fn is_empty(&self) -> bool {
        self.sentences.is_empty()
    }

    /// Build a document directly from raw text (user-supplied input path).
    pub fn from_text(id: &str, text: &str) -> Self {
        Self {
            id: id.to_string(),
            sentences: crate::text::split_sentences(text),
            reference: Vec::new(),
        }
    }
}
