//! Corpus substrate: document types + synthetic benchmark generation.
//!
//! The paper evaluates on CNN/DailyMail (20- and 50-sentence paragraphs)
//! and XSum (100-sentence paragraphs). Those datasets are not available in
//! this offline environment, so `synthetic` generates topic-structured
//! news-style documents whose (mu, beta) geometry matches what the
//! pipeline actually consumes (DESIGN.md §Substitutions), and `benchmark`
//! pins the seeded benchmark sets used by every experiment.

pub mod benchmark;
pub mod synthetic;

pub use benchmark::{benchmark_set, BenchmarkSet};
pub use synthetic::{Generator, GeneratorConfig};

/// A document: ordered sentences plus a construction-time reference
/// summary (indices of the generator's designated key-fact sentences),
/// used for ROUGE-style quality reporting.
#[derive(Debug, Clone)]
pub struct Document {
    pub id: String,
    pub sentences: Vec<String>,
    /// Indices (into `sentences`) of the reference key-fact sentences.
    pub reference: Vec<usize>,
}

impl Document {
    pub fn text(&self) -> String {
        self.sentences.join(" ")
    }

    pub fn len(&self) -> usize {
        self.sentences.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sentences.is_empty()
    }

    /// Build a document directly from raw text (user-supplied input path).
    pub fn from_text(id: &str, text: &str) -> Self {
        Self {
            id: id.to_string(),
            sentences: crate::text::split_sentences(text),
            reference: Vec::new(),
        }
    }
}
