//! Pinned benchmark sets mirroring the paper's evaluation inputs.
//!
//! | set name     | paper source            | docs | sentences | M |
//! |--------------|-------------------------|------|-----------|---|
//! | `cnn_dm_20`  | CNN/DailyMail 20-sent   | 20   | 20        | 6 |
//! | `cnn_dm_50`  | CNN/DailyMail 50-sent   | 20   | 50        | 6 |
//! | `xsum_100`   | XSum 100-sent           | 20   | 100       | 6 |
//! | `bench_10`   | Fig-3 10-sent set       | 20   | 10        | 3 |
//!
//! Seeds are fixed constants: every experiment in EXPERIMENTS.md runs over
//! byte-identical documents.

use anyhow::{bail, Result};

use super::synthetic::{Generator, GeneratorConfig};
use super::Document;

/// One pinned benchmark set (see the module table).
#[derive(Debug, Clone)]
pub struct BenchmarkSet {
    /// Set name (e.g. "cnn_dm_20").
    pub name: String,
    /// The pinned documents, in generation order.
    pub documents: Vec<Document>,
    /// Target summary length M for this set.
    pub summary_len: usize,
}

impl BenchmarkSet {
    /// Sentences per document in this set.
    pub fn doc_len(&self) -> usize {
        self.documents.first().map(|d| d.len()).unwrap_or(0)
    }
}

/// Deterministic seed per set (arbitrary but frozen).
fn set_seed(name: &str) -> u64 {
    crate::text::tokenize::fnv1a(name.as_bytes()) ^ 0xC0B1_E5E5_0000_0001
}

/// Build one of the pinned benchmark sets by name.
pub fn benchmark_set(name: &str) -> Result<BenchmarkSet> {
    let (count, n_sentences, summary_len, key_facts, topics) = match name {
        "cnn_dm_20" => (20, 20, 6, 6, 3),
        "cnn_dm_50" => (20, 50, 6, 6, 4),
        "xsum_100" => (20, 100, 6, 6, 5),
        "bench_10" => (20, 10, 3, 3, 2),
        _ => bail!("unknown benchmark set '{name}' (try cnn_dm_20, cnn_dm_50, xsum_100, bench_10)"),
    };
    let cfg = GeneratorConfig {
        topics_per_doc: topics,
        coherence: 0.55,
        key_facts,
    };
    let mut g = Generator::new(set_seed(name), cfg);
    Ok(BenchmarkSet {
        name: name.to_string(),
        documents: g.documents(name, count, n_sentences),
        summary_len,
    })
}

/// All pinned set names, in paper order.
pub const ALL_SETS: &[&str] = &["bench_10", "cnn_dm_20", "cnn_dm_50", "xsum_100"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_sets_build_with_expected_shapes() {
        for &name in ALL_SETS {
            let set = benchmark_set(name).unwrap();
            assert_eq!(set.documents.len(), 20, "{name}");
            let want = match name {
                "bench_10" => 10,
                "cnn_dm_20" => 20,
                "cnn_dm_50" => 50,
                "xsum_100" => 100,
                _ => unreachable!(),
            };
            for d in &set.documents {
                assert_eq!(d.len(), want, "{name}/{}", d.id);
            }
        }
    }

    #[test]
    fn sets_are_reproducible() {
        let a = benchmark_set("cnn_dm_20").unwrap();
        let b = benchmark_set("cnn_dm_20").unwrap();
        for (x, y) in a.documents.iter().zip(&b.documents) {
            assert_eq!(x.sentences, y.sentences);
        }
    }

    #[test]
    fn sets_differ_from_each_other() {
        let a = benchmark_set("cnn_dm_20").unwrap();
        let b = benchmark_set("cnn_dm_50").unwrap();
        assert_ne!(a.documents[0].sentences[0], b.documents[0].sentences[0]);
    }

    #[test]
    fn unknown_set_is_error() {
        assert!(benchmark_set("nope").is_err());
    }
}
