//! Synthetic topic-model news generator (CNN/DailyMail / XSum substitute).
//!
//! Documents are built LDA-style: each document draws a sparse topic
//! mixture; each sentence draws a topic from the mixture and realizes a
//! news-register template with content words from that topic's pool. The
//! resulting hashed-BoW / encoder cosine geometry has the properties the
//! paper's formulation depends on:
//!
//!   * all-pairs positive similarity (dense beta, all-to-all J),
//!   * same-topic sentences markedly more redundant than cross-topic,
//!   * a few designated "key fact" sentences with high centrality —
//!     these double as the reference summary for quality metrics.

use crate::util::rng::Pcg32;

use super::Document;

/// Topic word pools: subject nouns, verbs, object nouns, modifiers.
/// Eight news-ish topics; each sentence template mixes 3–5 content words
/// from one pool, so intra-topic lexical overlap is high.
struct Topic {
    subjects: &'static [&'static str],
    verbs: &'static [&'static str],
    objects: &'static [&'static str],
    modifiers: &'static [&'static str],
}

const TOPICS: &[Topic] = &[
    Topic {
        subjects: &["the government", "parliament", "the ministry", "officials", "the senate", "regulators"],
        verbs: &["announced", "approved", "rejected", "debated", "postponed", "unveiled"],
        objects: &["the budget proposal", "new legislation", "the reform package", "emergency funding", "the tax plan", "a trade agreement"],
        modifiers: &["after weeks of negotiation", "despite opposition", "in a late session", "under public pressure", "with a narrow majority"],
    },
    Topic {
        subjects: &["the company", "investors", "the startup", "shareholders", "the board", "analysts"],
        verbs: &["reported", "forecast", "slashed", "doubled", "restructured", "acquired"],
        objects: &["quarterly earnings", "its workforce", "the share price", "a rival firm", "operating margins", "its cloud division"],
        modifiers: &["amid market turmoil", "beating expectations", "for the third quarter", "after the merger", "despite rising costs"],
    },
    Topic {
        subjects: &["researchers", "the laboratory", "scientists", "the study", "the team", "engineers"],
        verbs: &["discovered", "published", "demonstrated", "measured", "simulated", "validated"],
        objects: &["a new material", "the experimental results", "a protein structure", "the prototype chip", "quantum behavior", "the clinical trial"],
        modifiers: &["in a peer-reviewed journal", "using the new instrument", "after years of work", "with unprecedented precision", "across many samples"],
    },
    Topic {
        subjects: &["the storm", "floodwaters", "emergency crews", "residents", "the wildfire", "forecasters"],
        verbs: &["battered", "evacuated", "warned", "submerged", "destroyed", "threatened"],
        objects: &["coastal towns", "thousands of homes", "the power grid", "low-lying districts", "the highway network", "farmland"],
        modifiers: &["overnight", "for the second day", "as rivers crested", "before dawn", "across the region"],
    },
    Topic {
        subjects: &["the team", "the striker", "the coach", "fans", "the champion", "the goalkeeper"],
        verbs: &["defeated", "signed", "injured", "celebrated", "benched", "transferred"],
        objects: &["the title holders", "a record contract", "the derby rivals", "the young defender", "the league trophy", "the penalty"],
        modifiers: &["in extra time", "before a sellout crowd", "after a video review", "on the final matchday", "against all odds"],
    },
    Topic {
        subjects: &["the hospital", "doctors", "health officials", "patients", "the clinic", "nurses"],
        verbs: &["treated", "vaccinated", "screened", "diagnosed", "discharged", "monitored"],
        objects: &["hundreds of cases", "the outbreak", "chronic conditions", "the new variant", "emergency admissions", "the therapy"],
        modifiers: &["during the surge", "under new guidelines", "with limited supplies", "at record pace", "across rural districts"],
    },
    Topic {
        subjects: &["the court", "prosecutors", "the jury", "the defendant", "judges", "lawyers"],
        verbs: &["convicted", "appealed", "dismissed", "sentenced", "indicted", "acquitted"],
        objects: &["the fraud charges", "the former executive", "the landmark case", "the settlement", "the corruption counts", "the verdict"],
        modifiers: &["after lengthy deliberation", "citing new evidence", "in a split decision", "behind closed doors", "on procedural grounds"],
    },
    Topic {
        subjects: &["the spacecraft", "mission control", "the satellite", "astronauts", "the rover", "the agency"],
        verbs: &["launched", "docked", "transmitted", "landed", "deployed", "orbited"],
        objects: &["the crew capsule", "new imagery", "the solar array", "the sample container", "the relay antenna", "the lunar module"],
        modifiers: &["after a flawless countdown", "on the far side", "ahead of schedule", "despite a fuel leak", "in low orbit"],
    },
];

/// Filler clauses mixed into non-key sentences (shared across topics;
/// they keep all-pairs similarity strictly positive, like real news prose).
const FILLERS: &[&str] = &[
    "according to people familiar with the matter",
    "officials said on Tuesday",
    "a spokesperson confirmed",
    "sources told reporters",
    "in a statement released later",
    "observers noted",
];

/// Generator knobs: topic sparsity, coherence, reference length.
#[derive(Debug, Clone)]
pub struct GeneratorConfig {
    /// Number of topics mixed per document (sparse mixture).
    pub topics_per_doc: usize,
    /// Probability that a sentence re-uses the previous sentence's topic
    /// (topical coherence -> redundancy clusters).
    pub coherence: f64,
    /// Number of designated key-fact sentences (reference summary length).
    pub key_facts: usize,
}

impl Default for GeneratorConfig {
    fn default() -> Self {
        Self {
            topics_per_doc: 3,
            coherence: 0.55,
            key_facts: 6,
        }
    }
}

/// Seeded document generator.
pub struct Generator {
    cfg: GeneratorConfig,
    rng: Pcg32,
}

impl Generator {
    /// Generator with an explicit config.
    pub fn new(seed: u64, cfg: GeneratorConfig) -> Self {
        Self {
            cfg,
            rng: Pcg32::new(seed, 0x5EED),
        }
    }

    /// Generator with the default config.
    pub fn with_seed(seed: u64) -> Self {
        Self::new(seed, GeneratorConfig::default())
    }

    fn pick<'a>(&mut self, pool: &[&'a str]) -> &'a str {
        pool[self.rng.below(pool.len() as u32) as usize]
    }

    /// One sentence from `topic`, optionally "key" (richer, no filler —
    /// higher centrality by construction).
    fn sentence(&mut self, topic: usize, key: bool) -> String {
        let t = &TOPICS[topic];
        let subj = self.pick(t.subjects);
        let verb = self.pick(t.verbs);
        let obj = self.pick(t.objects);
        let modi = self.pick(t.modifiers);
        let mut s = if key {
            // key facts stack two topic clauses: lexically central
            let verb2 = self.pick(t.verbs);
            let obj2 = self.pick(t.objects);
            format!("{subj} {verb} {obj} {modi} and {verb2} {obj2}")
        } else if self.rng.bernoulli(0.45) {
            let filler = self.pick(FILLERS);
            format!("{subj} {verb} {obj} {modi}, {filler}")
        } else {
            format!("{subj} {verb} {obj} {modi}")
        };
        // sentence-case + period
        let mut c = s.chars();
        if let Some(f) = c.next() {
            s = f.to_uppercase().collect::<String>() + c.as_str();
        }
        s.push('.');
        s
    }

    /// Generate one document with exactly `n_sentences` sentences.
    pub fn document(&mut self, id: &str, n_sentences: usize) -> Document {
        assert!(n_sentences >= self.cfg.key_facts, "too short for key facts");
        // sparse topic mixture
        let k = self.cfg.topics_per_doc.min(TOPICS.len());
        let doc_topics = self.rng.sample_indices(TOPICS.len(), k);

        // spread key facts across the document
        let mut key_slots: Vec<usize> = (0..self.cfg.key_facts)
            .map(|i| i * n_sentences / self.cfg.key_facts)
            .collect();
        key_slots.dedup();

        let mut sentences = Vec::with_capacity(n_sentences);
        let mut prev_topic = doc_topics[0];
        for i in 0..n_sentences {
            let topic = if self.rng.bernoulli(self.cfg.coherence) {
                prev_topic
            } else {
                doc_topics[self.rng.below(doc_topics.len() as u32) as usize]
            };
            prev_topic = topic;
            let key = key_slots.contains(&i);
            sentences.push(self.sentence(topic, key));
        }
        Document {
            id: id.to_string(),
            sentences,
            reference: key_slots,
        }
    }

    /// Generate `count` documents of `n_sentences` each.
    pub fn documents(&mut self, prefix: &str, count: usize, n_sentences: usize) -> Vec<Document> {
        (0..count)
            .map(|i| self.document(&format!("{prefix}-{i:03}"), n_sentences))
            .collect()
    }

    /// Generate a LONG document (hundreds to thousands of sentences —
    /// the tree/streaming workloads): topical sections of 20–60
    /// sentences, each section drawing a fresh sparse topic mixture, so
    /// redundancy clusters stay local the way archival news pages do.
    /// Key facts are spread across the whole document like
    /// [`document`](Generator::document)'s.
    pub fn long_document(&mut self, id: &str, n_sentences: usize) -> Document {
        assert!(n_sentences >= self.cfg.key_facts, "too short for key facts");
        let mut key_slots: Vec<usize> = (0..self.cfg.key_facts)
            .map(|i| i * n_sentences / self.cfg.key_facts)
            .collect();
        key_slots.dedup();

        let k = self.cfg.topics_per_doc.min(TOPICS.len());
        let mut sentences = Vec::with_capacity(n_sentences);
        let mut section_topics = self.rng.sample_indices(TOPICS.len(), k);
        let mut section_left = 0usize;
        let mut prev_topic = section_topics[0];
        for i in 0..n_sentences {
            if section_left == 0 {
                // new section: fresh topic mixture, 20–60 sentences
                section_topics = self.rng.sample_indices(TOPICS.len(), k);
                section_left = 20 + self.rng.below(41) as usize;
                prev_topic = section_topics[0];
            }
            section_left -= 1;
            let topic = if self.rng.bernoulli(self.cfg.coherence) {
                prev_topic
            } else {
                section_topics[self.rng.below(section_topics.len() as u32) as usize]
            };
            prev_topic = topic;
            sentences.push(self.sentence(topic, key_slots.contains(&i)));
        }
        Document {
            id: id.to_string(),
            sentences,
            reference: key_slots,
        }
    }

    /// Generate a streaming feed: one long document plus a seeded ragged
    /// chunking of its sentences (chunk sizes uniform in
    /// `1..=2*mean_chunk-1`, so the mean is `mean_chunk`) — the input
    /// shape of `SUMMARIZE_STREAM` sessions and the batching-invariance
    /// tests.
    pub fn feed(&mut self, id: &str, n_sentences: usize, mean_chunk: usize) -> StreamingFeed {
        let doc = self.long_document(id, n_sentences);
        let mean = mean_chunk.max(1);
        let mut chunks = Vec::new();
        let mut at = 0usize;
        while at < n_sentences {
            let size = (1 + self.rng.below((2 * mean) as u32 - 1) as usize)
                .min(n_sentences - at);
            chunks.push(doc.sentences[at..at + size].to_vec());
            at += size;
        }
        StreamingFeed { doc, chunks }
    }
}

/// A streaming workload: a long document plus the chunk boundaries it
/// arrives in (see [`Generator::feed`]).
#[derive(Debug, Clone)]
pub struct StreamingFeed {
    /// The full document (ground truth for invariance checks).
    pub doc: Document,
    /// The arrival chunks: concatenated, they are exactly
    /// `doc.sentences`.
    pub chunks: Vec<Vec<String>>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn exact_sentence_count() {
        let mut g = Generator::with_seed(1);
        for n in [10, 20, 50, 100] {
            let d = g.document("t", n);
            assert_eq!(d.len(), n);
        }
    }

    #[test]
    fn deterministic_from_seed() {
        let d1 = Generator::with_seed(7).document("a", 20);
        let d2 = Generator::with_seed(7).document("a", 20);
        assert_eq!(d1.sentences, d2.sentences);
        let d3 = Generator::with_seed(8).document("a", 20);
        assert_ne!(d1.sentences, d3.sentences);
    }

    #[test]
    fn sentences_survive_the_splitter() {
        // generated text re-split must give back the same sentence count —
        // guards against generator/splitter drift
        let mut g = Generator::with_seed(3);
        let d = g.document("t", 20);
        let resplit = crate::text::split_sentences(&d.text());
        assert_eq!(resplit.len(), d.len(), "{resplit:?}");
    }

    #[test]
    fn reference_indices_valid_and_distinct() {
        let mut g = Generator::with_seed(4);
        let d = g.document("t", 20);
        let set: HashSet<_> = d.reference.iter().collect();
        assert_eq!(set.len(), d.reference.len());
        assert!(d.reference.iter().all(|&i| i < d.len()));
        assert_eq!(d.reference.len(), 6);
    }

    #[test]
    fn long_documents_have_exact_counts_and_valid_references() {
        let mut g = Generator::with_seed(9);
        for n in [150usize, 600, 2000] {
            let d = g.long_document("long", n);
            assert_eq!(d.len(), n);
            let refs: HashSet<_> = d.reference.iter().collect();
            assert_eq!(refs.len(), d.reference.len());
            assert!(d.reference.iter().all(|&i| i < n));
        }
        // deterministic from the seed
        let a = Generator::with_seed(10).long_document("l", 500);
        let b = Generator::with_seed(10).long_document("l", 500);
        assert_eq!(a.sentences, b.sentences);
    }

    #[test]
    fn feeds_chunk_the_document_exactly() {
        let mut g = Generator::with_seed(11);
        let feed = g.feed("feed", 317, 12);
        assert_eq!(feed.doc.len(), 317);
        let rejoined: Vec<String> = feed.chunks.iter().flatten().cloned().collect();
        assert_eq!(rejoined, feed.doc.sentences);
        assert!(feed.chunks.iter().all(|c| !c.is_empty() && c.len() <= 23));
        // same seed, same chunking
        let again = Generator::with_seed(11).feed("feed", 317, 12);
        let sizes = |f: &StreamingFeed| f.chunks.iter().map(|c| c.len()).collect::<Vec<_>>();
        assert_eq!(sizes(&feed), sizes(&again));
    }

    #[test]
    fn documents_are_lexically_diverse() {
        let mut g = Generator::with_seed(5);
        let d = g.document("t", 30);
        let distinct: HashSet<_> = d.sentences.iter().collect();
        // stochastic templates: near-total uniqueness expected
        assert!(distinct.len() >= 28, "only {} distinct", distinct.len());
    }

    #[test]
    fn intra_topic_overlap_exceeds_cross_topic() {
        // lexical-overlap sanity proxy for the beta structure: average
        // word-overlap between same-topic sentence pairs should beat
        // cross-topic pairs. Use two single-topic docs.
        let mut g = Generator::new(
            11,
            GeneratorConfig {
                topics_per_doc: 1,
                coherence: 1.0,
                key_facts: 3,
            },
        );
        let a = g.document("a", 12);
        let b = g.document("b", 12);
        let words = |s: &str| {
            crate::text::tokenize(s)
                .into_iter()
                .map(|w| w.to_ascii_lowercase())
                .collect::<HashSet<_>>()
        };
        let jaccard = |x: &HashSet<String>, y: &HashSet<String>| {
            let i = x.intersection(y).count() as f64;
            let u = x.union(y).count() as f64;
            i / u
        };
        let wa: Vec<_> = a.sentences.iter().map(|s| words(s)).collect();
        let wb: Vec<_> = b.sentences.iter().map(|s| words(s)).collect();
        let mut intra = vec![];
        for i in 0..wa.len() {
            for j in (i + 1)..wa.len() {
                intra.push(jaccard(&wa[i], &wa[j]));
            }
        }
        let mut cross = vec![];
        for x in &wa {
            for y in &wb {
                cross.push(jaccard(x, y));
            }
        }
        let mi = crate::util::stats::mean(&intra);
        let mc = crate::util::stats::mean(&cross);
        assert!(
            mi > mc,
            "intra-topic overlap {mi:.3} not above cross-topic {mc:.3}"
        );
    }
}
